"""ExecuteMerge — budget-enforced streaming execution (paper §5, Algorithm 2).

The engine enforces a planner-produced plan π:

  * every base block is read and every output block is written — the
    output is always a *complete checkpoint* (C_base, C_out intrinsic);
  * expert blocks are read **iff** selected by π (budget soundness:
    realized expert I/O <= Ĉ_expert(π) <= B);
  * writes are staged, hash-validated, and atomically published as an
    immutable snapshot with full lineage (touch maps + per-block expert
    coverage).

Three compute paths apply the operator:
  ``stream``    — per-block numpy apply (paper-faithful CPU streaming);
  ``batched``   — stacks same-width blocks and calls the jitted kernel
                  wrappers in :mod:`repro.kernels.ops` (TPU-native path;
                  beyond-paper optimization, tolerance-level equivalent);
  ``pipelined`` — the overlapped streaming engine (default for the v2
                  Session/CLI): a prefetch stage reads base + plan-selected
                  expert blocks ahead of compute over a small thread pool,
                  a compute stage drains bounded windows and applies the
                  operator vectorized per (K_sel, width) group, and a
                  write-behind stage streams finished blocks into the
                  staging writer — so wall-time approaches
                  max(read, compute, write) instead of their sum, with
                  resident memory bounded by the window (no whole-tensor
                  buffering).  Outputs are **bit-identical** to ``stream``
                  and expert I/O follows the plan's realized read set
                  exactly, so budget soundness accounting is unchanged.
                  See docs/EXECUTION.md.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import blocks as blk
from repro.core.catalog import Catalog
from repro.core.delta_iterator import DeltaIterator
from repro.core.operators import apply_operator, dare_mask_batch
from repro.core.plan import MergePlan
from repro.core.transactions import TransactionManager
from repro.store.integrity import VerifyPolicy, attach_verifier
from repro.store.iostats import IOStats
from repro.store.journal import ResumeState
from repro.store.snapshot import SnapshotStore, WriteBehindWriter
from repro.testing.chaos import chaos_point


class MergeCancelled(RuntimeError):
    """Cooperative cancellation: raised at an executor checkpoint when
    the caller's cancel event fires.  The in-flight transaction aborts
    (staged output discarded, nothing published) before this propagates."""


#: progress callback signature: (blocks_done, blocks_total)
ProgressFn = Callable[[int, int], None]


def _check_cancel(cancel: Optional[threading.Event], sid: str) -> None:
    if cancel is not None and cancel.is_set():
        raise MergeCancelled(f"merge {sid} cancelled at executor checkpoint")


def _ranges_from_indices(idxs: List[int]) -> List[Tuple[int, int]]:
    """Compress sorted block indexes into [start, end) ranges (TouchMap)."""
    if not idxs:
        return []
    runs = []
    start = prev = idxs[0]
    for i in idxs[1:]:
        if i == prev + 1:
            prev = i
            continue
        runs.append((start, prev + 1))
        start = prev = i
    runs.append((start, prev + 1))
    return runs


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Tuning knobs for the overlapped (``compute="pipelined"``) engine.

    window_blocks     — blocks per compute window (vectorization batch and
                        the unit of bounded buffering).
    prefetch_windows  — max fully-read windows queued ahead of compute
                        (prefetch depth; back-pressure beyond this).
    read_threads      — thread-pool size for base/expert block reads
                        (pread-based readers, safe under concurrency).
    write_queue_blocks — bound on output blocks queued behind compute.
    coalesce_gap_bytes — tolerated unselected bytes between two selected
                        ranges before a coalesced read is split (0 =
                        merge only strictly adjacent ranges).  On
                        high-latency shared storage a slightly larger
                        sequential read beats an extra round trip; gap
                        bytes are accounted as ``other``, never against
                        the expert budget (see
                        ``ModelReader.read_blocks_coalesced``).
    kernel            — "numpy": vectorized numpy apply, bit-identical to
                        the stream path (default; the golden-test
                        invariant).  "jax": the jitted kernel wrappers in
                        :mod:`repro.kernels.ops` (Pallas on TPU) —
                        tolerance-level equivalent on CPU, use on
                        accelerators.
    """

    window_blocks: int = 32
    prefetch_windows: int = 2
    read_threads: int = 4
    write_queue_blocks: int = 64
    kernel: str = "numpy"
    coalesce_gap_bytes: int = 0

    @classmethod
    def for_remote(cls) -> "PipelineConfig":
        """Deeper defaults for remote-backed readers: more read threads
        and more windows in flight so per-request remote latency is
        hidden behind compute, plus gap-tolerant coalescing (a slightly
        larger sequential GET beats an extra round trip)."""
        return cls(
            prefetch_windows=4,
            read_threads=8,
            coalesce_gap_bytes=1 << 14,
        )

    # NOTE on the numpy kernel: blocks are *prepared* (expert deltas
    # pulled, upcast, DARE masks generated) window-at-a-time on the
    # prefetch pool, but the operator applies per block — profiling shows
    # per-block working sets stay L2-resident while (NB, K, w) stacks are
    # memory-bandwidth-bound and *slower* on CPU.  The jax kernel groups
    # whole windows by (K_sel, width) and calls the jitted wrappers,
    # where batching does pay (one dispatch per group, Pallas on TPU).

    def validate(self) -> None:
        if self.window_blocks < 1:
            raise ValueError(f"window_blocks must be >= 1, got {self.window_blocks}")
        if self.prefetch_windows < 1:
            raise ValueError(
                f"prefetch_windows must be >= 1, got {self.prefetch_windows}"
            )
        if self.read_threads < 1:
            raise ValueError(f"read_threads must be >= 1, got {self.read_threads}")
        if self.write_queue_blocks < 1:
            raise ValueError(
                f"write_queue_blocks must be >= 1, got {self.write_queue_blocks}"
            )
        if self.kernel not in ("numpy", "jax"):
            raise ValueError(f"unknown pipeline kernel {self.kernel!r}")
        if self.coalesce_gap_bytes < 0:
            raise ValueError(
                f"coalesce_gap_bytes must be >= 0, got {self.coalesce_gap_bytes}"
            )

    def max_resident_blocks(self, n_experts: int) -> int:
        """Bound on simultaneously resident input block slots: up to
        ``prefetch_windows + 1`` windows staging on the pool, plus one
        staged window in the producer's hand while it blocks on the full
        window queue, plus ``prefetch_windows`` queued, plus one in
        compute; each window may transiently hold, per block, the base
        block, K expert cache blocks, and the K pulled delta rows
        materialized from them (write-behind output is bounded separately
        by ``write_queue_blocks``)."""
        windows_in_flight = 2 * self.prefetch_windows + 3
        return windows_in_flight * self.window_blocks * (1 + 2 * n_experts)


class MergeResult:
    def __init__(self, sid: str, manifest: Dict, stats: Dict):
        self.sid = sid
        self.manifest = manifest
        self.stats = stats

    def __repr__(self) -> str:  # pragma: no cover
        return f"MergeResult(sid={self.sid!r}, stats={self.stats})"


def _is_mergeable(spec) -> bool:
    """Float tensors are merged; ints/bools pass through as base."""
    return np.issubdtype(
        np.asarray([], dtype=spec.dtype).dtype, np.floating
    ) or spec["dtype"] in ("bfloat16", "float16", "float32", "float64")


def _tiered_readers_behind(readers) -> List[object]:
    """Distinct TieredReader objects behind the given readers (direct or
    wrapped in a CachingModelReader).  Used to (a) auto-deepen the
    pipelined prefetch for remote-latency hiding and (b) widen budget
    slack by honestly-recorded eviction re-fetches."""
    out: List[object] = []
    for r in readers:
        inner = getattr(r, "_reader", r)
        if hasattr(inner, "evict_refetch_bytes") and all(
            inner is not x for x in out
        ):
            out.append(inner)
    return out


def _packed_layouts_behind(expert_readers: Dict[str, object]) -> List[object]:
    """Distinct PackedLayout objects serving the given readers — direct
    members or members wrapped in a CachingModelReader (the Session's
    shared-read injection).  Needed so budget enforcement can widen its
    slack by honestly-recorded extent re-reads when the caller opened
    the layout with a ``max_pinned_bytes`` cap."""
    out: List[object] = []
    for r in expert_readers.values():
        inner = getattr(r, "_reader", r)
        layout = getattr(inner, "layout", None)
        if layout is not None and all(layout is not x for x in out):
            out.append(layout)
    return out


def execute_merge(
    plan: MergePlan,
    snapshots: SnapshotStore,
    catalog: Catalog,
    sid: Optional[str] = None,
    txn: Optional[TransactionManager] = None,
    coalesce: bool = True,
    compute: str = "stream",
    validate: bool = True,
    enforce_budget: bool = True,
    verify=True,
    expert_readers: Optional[Dict[str, object]] = None,
    pipeline: Optional[PipelineConfig] = None,
    cancel: Optional[threading.Event] = None,
    progress: Optional[ProgressFn] = None,
    resume: Optional[ResumeState] = None,
) -> MergeResult:
    """Run Algorithm 2 for plan π and return the committed snapshot.

    ``expert_readers`` optionally injects pre-opened (possibly caching)
    readers keyed by expert id — the API v2 batch session passes shared
    :class:`~repro.store.blockcache.CachingModelReader` instances here so
    one physical scan of an expert block fans out to every job in the
    batch that selected it.  Injected readers are owned by the caller
    and are NOT closed on return.

    ``pipeline`` tunes the overlapped engine when ``compute="pipelined"``
    (ignored otherwise); ``None`` uses :class:`PipelineConfig` defaults.

    ``cancel`` is a cooperative cancellation flag (any object with a
    boolean ``is_set()``): the engines poll it at block/window
    checkpoints and raise :class:`MergeCancelled` when it fires — the
    transaction aborts crash-safely, staged output is discarded, and no
    snapshot is published.  ``progress`` is called as
    ``progress(blocks_done, blocks_total)`` as output blocks retire (per
    tensor on the synchronous engines, per window on the pipelined one).

    ``verify`` enables verify-on-read (:mod:`repro.store.integrity`):
    every block read during the merge is checked against the catalog's
    ANALYZE block hash (packed extents against their content-hash keys),
    with read-repair on the tiered/packed paths and a typed
    :class:`~repro.store.integrity.CorruptBlockError` when repair is
    impossible.  ``True`` (default) verifies every tier; pass a
    :class:`~repro.store.integrity.VerifyPolicy` to opt flat-local reads
    out of hashing on trusted hot paths; ``False`` disables entirely.
    Models without catalog analysis at this block size are served
    unverified (no contract exists for them).

    ``resume`` is a validated :class:`~repro.store.journal.ResumeState`
    (from ``TransactionManager.recover()`` / ``prepare_resume``): the
    engines skip every block below its per-tensor high-water marks —
    no base read, no expert read, no write — and the budget accounting
    only sees the residual set.  The resumed snapshot is bit-identical
    to an uninterrupted run.  A resume state whose plan digest does not
    match ``plan`` is discarded and the merge restarts from scratch
    (staged blocks computed under a different plan are worthless).
    """
    t0 = time.time()
    stats: IOStats = snapshots.stats
    expert_read_before = stats.c_expert
    txn = txn or TransactionManager(snapshots, catalog)
    sid = sid or TransactionManager.new_sid()

    resumed_from: Dict[str, int] = {}
    if resume is not None:
        if resume.sid != sid:
            raise ValueError(
                f"resume state is for sid {resume.sid!r}, not {sid!r}"
            )
        if resume.plan_digest != plan.digest():
            # the plan changed under the journal (different budget /
            # selection): staged blocks were computed under the old plan
            # and can never validate against the new one — start fresh
            resume.discard()
            resume = None
        else:
            resumed_from = {
                t: n for t, n in resume.completed.items() if n > 0
            }
            # residual accounting: the skipped logical volume is recorded
            # (never into any C_* term) so tests can assert that crashed +
            # resumed reads cover each selected byte exactly once
            for t, tr in resume.tensors.items():
                if tr.n_validated:
                    stats.record_skip("base", tr.validated_nbytes)
                    stats.record_skip(
                        "expert",
                        resume.skipped_expert_bytes(plan.reverse_index(t), t),
                    )
                    stats.record_skip("out", tr.validated_nbytes)

    kernel_ops = None
    if compute == "batched":
        from repro.kernels import ops as kernel_ops  # lazy: jax import
    elif compute == "pipelined":
        # default PipelineConfig is resolved *after* readers are open, so
        # remote-backed readers can deepen the prefetch (see below); an
        # explicit config is validated here, before any txn state exists
        if pipeline is not None:
            pipeline.validate()
    elif compute != "stream":
        raise ValueError(f"unknown compute mode {compute!r}")
    owns_expert_readers = expert_readers is None
    if expert_readers is not None:
        # validate before any transaction/reader state exists
        missing = [e for e in plan.expert_ids if e not in expert_readers]
        if missing:
            raise KeyError(f"injected expert_readers missing {missing}")

    # -- Transaction and staging -----------------------------------------
    if resume is not None:
        writer = txn.begin(resume=resume)
    else:
        writer = txn.begin(sid=sid, plan=plan)
    touch: Dict[str, List[int]] = {}
    coverage_rows: List[Tuple[str, int, str]] = []

    base_reader = snapshots.models.open_model(plan.base_id)
    packed_layout = None
    if expert_readers is None:
        if getattr(plan, "layout_id", None):
            # packed physical layout: one opened layout serves every
            # expert — each unique extent is read once and fanned out to
            # all (expert, block) consumers, elided blocks cost nothing,
            # and physical reads are tagged ``expert_packed``.
            packed_layout = snapshots.packed.open_layout(plan.layout_id)
            expert_readers = {
                e: packed_layout.open_member(e) for e in plan.expert_ids
            }
        else:
            expert_readers = {
                e: snapshots.models.open_model(e) for e in plan.expert_ids
            }
    # layouts serving this merge (owned or injected): extent re-reads they
    # record under memory-cap pressure widen the budget slack below
    merge_layouts = (
        [packed_layout] if packed_layout is not None
        else _packed_layouts_behind(expert_readers)
    )
    reread_before = sum(l.reread_bytes for l in merge_layouts)
    # tiered (remote-backed) readers serving this merge: a disk-cache
    # extent evicted between plan and read is honestly re-fetched from
    # remote — those bytes widen the budget slack, mirroring packed
    # extent re-reads under memory-cap pressure
    tiered_readers = _tiered_readers_behind(
        [base_reader, *expert_readers.values()]
    )
    evict_refetch_before = sum(r.evict_refetch_bytes for r in tiered_readers)
    # -- verify-on-read (repro.store.integrity) --------------------------
    # attach a catalog-hash verifier per reader (packed members instead
    # toggle their layout's extent self-check); a disabled policy
    # explicitly detaches, so injected readers reused across windows
    # honor this window's knob
    verify_policy = VerifyPolicy.coerce(verify)
    verifiers = []
    for mid, r in [(plan.base_id, base_reader), *expert_readers.items()]:
        v = attach_verifier(r, catalog, mid, plan.block_size, verify_policy)
        if v is not None:
            verifiers.append(v)
    # read-repair traffic (corrupt cache extents refilled, corrupt packed
    # extents served from flat sources) widens budget slack below — the
    # plan could not have priced corruption in
    repair_before = sum(
        getattr(r, "repair_bytes", 0) for r in tiered_readers
    ) + sum(getattr(l, "repair_bytes", 0) for l in merge_layouts)
    if compute == "pipelined" and pipeline is None:
        pipeline = (
            PipelineConfig.for_remote()
            if any(
                getattr(r, "prefers_deep_prefetch", False)
                for r in tiered_readers
            )
            else PipelineConfig()
        )
    if compute == "pipelined" and pipeline.kernel == "jax" and kernel_ops is None:
        from repro.kernels import ops as kernel_ops  # lazy: jax import
    theta = dict(plan.theta)
    seed = int(theta.get("seed", 0))
    is_dare = plan.op.lower() == "dare"

    realized_expert_blocks = 0
    pipe_stats: Optional[Dict] = None
    progress_total = 0
    progress_done = 0
    if progress is not None:
        progress_total = sum(
            blk.num_blocks(base_reader.spec(t).nbytes, plan.block_size)
            for t in plan.tensor_order
        )
    try:
        # -- (1) Stream selected blocks under plan π -----------------------
        _check_cancel(cancel, sid)
        if compute == "pipelined":
            engine = _PipelineEngine(
                plan, writer, base_reader, expert_readers, theta, seed,
                is_dare, pipeline, kernel_ops, coalesce, touch, coverage_rows,
                cancel=cancel, progress=progress,
                progress_total=progress_total,
                resume=resume,
            )
            realized_expert_blocks, pipe_stats = engine.run()
        else:
            for tensor_id in plan.tensor_order:
                _check_cancel(cancel, sid)
                chaos_point("executor:tensor")
                spec = base_reader.spec(tensor_id)
                writer.begin_tensor(tensor_id, spec.shape, spec.dtype)
                rev = plan.reverse_index(tensor_id)
                mergeable = _is_mergeable(spec)
                n_blocks = blk.num_blocks(spec.nbytes, plan.block_size)
                skip = min(resumed_from.get(tensor_id, 0), n_blocks)
                D = DeltaIterator(
                    tensor_id, plan, base_reader, expert_readers,
                    coalesce=coalesce, read_from=skip,
                )
                touched: List[int] = []
                if skip:
                    # lineage already earned by the dead run, re-seeded
                    # straight from the journal — zero I/O
                    for b, experts in resume.coverage(tensor_id):
                        touched.append(b)
                        coverage_rows.append((tensor_id, b, experts))

                if compute == "batched" and mergeable:
                    _run_tensor_batched(
                        kernel_ops, plan, writer, base_reader, D, rev,
                        tensor_id, spec, n_blocks, theta, seed, is_dare,
                        touched, coverage_rows, cancel=cancel, sid=sid,
                        skip=skip,
                    )
                    realized_expert_blocks += sum(
                        len(v) for b, v in rev.items() if b >= skip
                    )
                else:
                    for b in range(skip, n_blocks):
                        _check_cancel(cancel, sid)
                        chaos_point("executor:block")
                        x0 = base_reader.read_block(
                            tensor_id, b, plan.block_size, "base"
                        )
                        experts_csv = None
                        if mergeable and b in rev:
                            deltas, eidxs, eids = D.pull(b, x0)
                            realized_expert_blocks += len(eids)
                            if is_dare and len(eids):
                                theta["_masks"] = dare_mask_batch(
                                    seed, eidxs, tensor_id, b, x0.size,
                                    float(theta.get("density", 0.5)),
                                )
                            x = apply_operator(x0, deltas, plan.op, theta)
                            theta.pop("_masks", None)
                            if len(eids):
                                touched.append(b)
                                experts_csv = ",".join(eids)
                                coverage_rows.append(
                                    (tensor_id, b, experts_csv)
                                )
                        else:
                            x = x0  # base passthrough (no expert selected)
                        writer.write_block(tensor_id, b, x, experts=experts_csv)
                writer.finish_tensor(tensor_id)
                touch[tensor_id] = touched
                if progress is not None:
                    progress_done += n_blocks
                    progress(progress_done, progress_total)

        # -- (2) Validate and atomically publish --------------------------
        if validate:
            writer.validate_hashes()

        realized_expert_bytes = stats.c_expert - expert_read_before
        if enforce_budget and plan.budget_b >= 0:
            # Budget soundness (§5.1): realized <= planned <= B, up to the
            # storage layer's accounting granularity (adapters read factor
            # tensors, which are far below the planned block bytes).
            slack = 2 * plan.block_size
            if merge_layouts:
                # the planner charges each shared extent once; when a
                # max_pinned_bytes cap forced an extent to be re-read for
                # a later consumer, those honestly-recorded bytes are a
                # memory-cap tradeoff, not a plan violation
                slack += (
                    sum(l.reread_bytes for l in merge_layouts) - reread_before
                )
            if tiered_readers:
                # disk-cache extents evicted mid-run are re-fetched from
                # remote at full price — a cache-pressure tradeoff the
                # plan could not have foreseen, not a plan violation
                slack += (
                    sum(r.evict_refetch_bytes for r in tiered_readers)
                    - evict_refetch_before
                )
            if tiered_readers or merge_layouts:
                # read-repair refetches (expert_repair) are honest extra
                # bytes forced by detected corruption, never plannable
                slack += (
                    sum(getattr(r, "repair_bytes", 0) for r in tiered_readers)
                    + sum(getattr(l, "repair_bytes", 0) for l in merge_layouts)
                    - repair_before
                )
            if realized_expert_bytes > plan.c_expert_hat + slack:
                raise RuntimeError(
                    f"budget soundness violated: realized expert bytes "
                    f"{realized_expert_bytes} > planned {plan.c_expert_hat}"
                )

        manifest = {
            "sid": sid,
            "plan_id": plan.plan_id,
            "base_id": plan.base_id,
            "expert_ids": plan.expert_ids,
            "op": plan.op,
            "theta": {k: v for k, v in theta.items() if not k.startswith("_")},
            "budget_b": plan.budget_b,
            "c_expert_hat": plan.c_expert_hat,
            "c_expert_logical_hat": plan.logical_hat,
            "c_expert_run": realized_expert_bytes,
            "plan_digest": plan.digest(),
            "block_size": plan.block_size,
            "layout_id": plan.layout_id,
        }
        sid = txn.atomic_publish(writer, manifest)
        manifest["output_root"] = snapshots.manifest(sid)["output_root"]
        txn.commit_record(sid, manifest)
        catalog.record_touch_map(
            sid, {t: _ranges_from_indices(ix) for t, ix in touch.items()}
        )
        catalog.record_coverage(sid, coverage_rows)
        if plan.parent_sids:
            catalog.record_dag_edges(
                sid,
                [
                    (p, "base" if p == plan.base_id else "expert")
                    for p in plan.parent_sids
                ],
            )
        # lineage is in the catalog — only now is the journal obsolete
        # (a crash since publish replays coverage from it at recovery)
        if writer.journal is not None:
            writer.journal.remove()
        txn.commit()
    except Exception:
        txn.abort()
        raise
    finally:
        base_reader.close()
        if owns_expert_readers:
            for r in expert_readers.values():
                r.close()
            if packed_layout is not None:
                packed_layout.close()

    run_stats = {
        "seconds": time.time() - t0,
        "c_expert_run": realized_expert_bytes,
        "c_expert_hat": plan.c_expert_hat,
        "realized_expert_blocks": realized_expert_blocks,
        "compute": compute,
        "coalesce": coalesce,
        "resumed_blocks": sum(resumed_from.values()),
    }
    if verify_policy is not None:
        run_stats["verify"] = {
            "verified_blocks": sum(v.verified_blocks for v in verifiers),
            "repaired_blocks": sum(v.repaired_blocks for v in verifiers),
            "corrupt_blocks": sum(v.corrupt_blocks for v in verifiers),
            "repair_bytes": (
                sum(getattr(r, "repair_bytes", 0) for r in tiered_readers)
                + sum(getattr(l, "repair_bytes", 0) for l in merge_layouts)
                - repair_before
            ),
        }
    if pipe_stats is not None:
        run_stats["pipeline"] = pipe_stats
    return MergeResult(sid, manifest, run_stats)


def _run_tensor_batched(
    kernel_ops,
    plan: MergePlan,
    writer,
    base_reader,
    D: DeltaIterator,
    rev: Dict[int, List[str]],
    tensor_id: str,
    spec,
    n_blocks: int,
    theta: Dict,
    seed: int,
    is_dare: bool,
    touched: List[int],
    coverage_rows: List[Tuple[str, int, str]],
    cancel: Optional[threading.Event] = None,
    sid: str = "",
    skip: int = 0,
) -> None:
    """Batched compute path: group blocks by (K_sel, width) and apply the
    jitted kernel once per group.  Physical I/O identical to the stream
    path; only operator application is vectorized.  ``skip`` is the
    resume high-water mark: blocks below it are already staged and are
    neither read nor written again."""
    # gather the residual blocks first (they stream block-by-block for I/O
    # accounting, then math runs in grouped batches)
    base_blocks: Dict[int, np.ndarray] = {}
    deltas_per_block: Dict[int, Optional[np.ndarray]] = {}
    eidxs_per_block: Dict[int, List[int]] = {}
    experts_per_block: Dict[int, Optional[str]] = {}
    for b in range(skip, n_blocks):
        _check_cancel(cancel, sid)
        chaos_point("executor:block")
        x0 = base_reader.read_block(tensor_id, b, plan.block_size, "base")
        base_blocks[b] = x0
        experts_per_block[b] = None
        if b in rev:
            deltas, eidxs, eids = D.pull(b, x0)
            deltas_per_block[b] = deltas
            eidxs_per_block[b] = eidxs
            if len(eids):
                touched.append(b)
                experts_per_block[b] = ",".join(eids)
                coverage_rows.append((tensor_id, b, experts_per_block[b]))
        else:
            deltas_per_block[b] = None
            eidxs_per_block[b] = []

    out_blocks: Dict[int, np.ndarray] = {}
    groups: Dict[Tuple[int, int], List[int]] = {}
    for b in range(skip, n_blocks):
        d = deltas_per_block[b]
        if d is None or d.shape[0] == 0:
            out_blocks[b] = base_blocks[b]
            continue
        groups.setdefault((d.shape[0], base_blocks[b].size), []).append(b)

    for (k_sel, width), idxs in groups.items():
        x0s = np.stack([np.asarray(base_blocks[b], np.float32) for b in idxs])
        Ds = np.stack([deltas_per_block[b] for b in idxs])  # (nb, k, w)
        masks = None
        if is_dare:
            masks = np.stack(
                [
                    dare_mask_batch(
                        seed, eidxs_per_block[b], tensor_id, b, width,
                        float(theta.get("density", 0.5)),
                    )
                    for b in idxs
                ]
            )
        outs = kernel_ops.merge_blocks(plan.op, x0s, Ds, theta, masks=masks)
        outs = np.asarray(outs).astype(np.asarray(base_blocks[idxs[0]]).dtype)
        for j, b in enumerate(idxs):
            out_blocks[b] = outs[j]

    for b in range(skip, n_blocks):
        writer.write_block(
            tensor_id, b, out_blocks[b], experts=experts_per_block[b]
        )


# ======================================================================
# Pipelined streaming engine (compute="pipelined")
# ======================================================================

class _TensorTask:
    """Per-tensor state shared between the prefetch and compute stages."""

    __slots__ = ("tensor_id", "spec", "n_blocks", "mergeable", "rev", "D",
                 "touched")

    def __init__(self, tensor_id, spec, n_blocks, mergeable, rev, D):
        self.tensor_id = tensor_id
        self.spec = spec
        self.n_blocks = n_blocks
        self.mergeable = mergeable
        self.rev = rev
        self.D = D
        self.touched: List[int] = []


class _ResidencyGauge:
    """Counts in-flight input block slots (base + expert) across stages —
    the bounded-memory invariant is asserted against its peak."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.current = 0  # guarded-by: _lock
        self.peak = 0  # guarded-by: _lock

    def add(self, n: int) -> None:
        if n <= 0:
            return
        with self._lock:
            self.current += n
            if self.current > self.peak:
                self.peak = self.current

    def sub(self, n: int) -> None:
        if n <= 0:
            return
        with self._lock:
            self.current -= n


class _PipelineEngine:
    """Three overlapped stages over bounded queues (Algorithm 2, split):

        prefetch (thread + pool) --> [window queue] --> compute (caller
        thread) --> [write queue] --> write-behind (thread)

    The prefetch stage performs *all* physical input I/O: base blocks and
    the plan-selected expert blocks of each window (via the windowed
    :class:`DeltaIterator` hooks), over thread-safe pread readers.  The
    compute stage pulls deltas from the prefetched window cache (zero
    I/O), groups blocks by (K_sel, width) like the batched path — but
    windowed, so memory stays bounded — and applies the operator
    vectorized.  Finished blocks stream to the
    :class:`~repro.store.snapshot.WriteBehindWriter` so output writes
    overlap the next window's reads and compute.
    """

    _DONE = ("done", None, None, None)

    def __init__(
        self,
        plan: MergePlan,
        writer,
        base_reader,
        expert_readers: Dict[str, object],
        theta: Dict,
        seed: int,
        is_dare: bool,
        cfg: PipelineConfig,
        kernel_ops,
        coalesce: bool,
        touch: Dict[str, List[int]],
        coverage_rows: List[Tuple[str, int, str]],
        cancel: Optional[threading.Event] = None,
        progress: Optional[ProgressFn] = None,
        progress_total: int = 0,
        resume: Optional[ResumeState] = None,
        spans: Optional[Dict[str, Tuple[int, int]]] = None,
    ):
        self.plan = plan
        self.base_reader = base_reader
        self.expert_readers = expert_readers
        self.theta = theta
        self.seed = seed
        self.is_dare = is_dare
        self.cfg = cfg
        self.kernel_ops = kernel_ops  # None => bit-identical numpy kernel
        self.coalesce = coalesce
        self.touch = touch
        self.coverage_rows = coverage_rows
        self.cancel = cancel
        self.progress = progress
        self.progress_total = progress_total
        self.resume = resume
        # shard-worker mode: restrict the sweep to ``{tensor: (lo, hi)}``
        # half-open block spans.  Block indices stay GLOBAL (DARE masks,
        # coverage, and touch maps must match the single-process run
        # bit-for-bit); tensors absent from the map are skipped entirely.
        self.spans = spans
        self.resumed_from: Dict[str, int] = (
            {t: n for t, n in resume.completed.items() if n > 0}
            if resume is not None else {}
        )
        self.progress_done = sum(self.resumed_from.values())
        self.realized_expert_blocks = 0
        self.gauge = _ResidencyGauge()
        self.windows = 0
        self.wb = WriteBehindWriter(writer, cfg.write_queue_blocks)
        self.pool = ThreadPoolExecutor(
            max_workers=cfg.read_threads, thread_name_prefix="mergepipe-read"
        )
        self.q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch_windows)
        self.stop = threading.Event()

    # ------------------------------------------------------------- stage 1
    def _read_base_window(self, tensor_id: str, window: List[int]) -> Dict:
        if self.coalesce:
            out = self.base_reader.read_blocks_coalesced(
                tensor_id, window, self.plan.block_size, "base",
                gap_bytes=self.cfg.coalesce_gap_bytes,
            )
        else:
            out = {
                b: self.base_reader.read_block(
                    tensor_id, b, self.plan.block_size, "base"
                )
                for b in window
            }
        self.gauge.add(len(window))
        return out

    def _stage_window(self, task: _TensorTask, window: List[int]) -> Tuple:
        """One pool task = the full input side of one window: read the
        base run, read the plan-selected expert blocks, then pull/upcast
        the delta stacks and generate DARE masks — so the compute thread
        receives ready-to-apply inputs and only does operator math.
        Multiple windows stage concurrently on the pool (pread readers
        are offset-explicit, block sets are disjoint)."""
        # prompt failure propagation: a doomed merge (writer thread died)
        # must stop pouring expert reads into staging it will never keep
        self.wb.raise_if_failed()
        chaos_point("executor:prefetch")
        base_blocks = self._read_base_window(task.tensor_id, window)
        pulled: Dict[int, Tuple] = {}
        if task.D is not None:
            for si in range(task.D.n_sources):
                self.gauge.add(task.D.prefetch_source(si, window))
            density = float(self.theta.get("density", 0.5))
            for b in window:
                if b not in task.rev:
                    continue
                deltas, eidxs, eids = task.D.pull(b, base_blocks[b])
                masks = None
                if self.is_dare and eidxs:
                    masks = dare_mask_batch(
                        self.seed, eidxs, task.tensor_id, b,
                        base_blocks[b].size, density,
                    )
                pulled[b] = (deltas, eidxs, eids, masks)
                self.gauge.add(deltas.shape[0])
            # expert cache slots are now materialized into delta stacks
            self.gauge.sub(task.D.release_blocks(window))
        return base_blocks, pulled

    def _produce(self) -> None:
        try:
            # how many windows may be staging on the pool at once, beyond
            # the queued ones (the window queue itself is the main bound)
            lookahead = self.cfg.prefetch_windows + 1
            pending: List[Tuple] = []  # (kind, task, window, future|None)
            outstanding = 0

            def flush_one() -> None:
                nonlocal outstanding
                kind, task, window, fut = pending.pop(0)
                payload = None
                if fut is not None:
                    payload = fut.result()  # propagates staging errors
                    outstanding -= 1
                self._put((kind, task, window, payload))

            for tensor_id in self.plan.tensor_order:
                if self.spans is not None and tensor_id not in self.spans:
                    continue
                spec = self.base_reader.spec(tensor_id)
                n_blocks = blk.num_blocks(spec.nbytes, self.plan.block_size)
                mergeable = _is_mergeable(spec)
                rev = self.plan.reverse_index(tensor_id) if mergeable else {}
                lo, hi = 0, n_blocks
                if self.spans is not None:
                    lo, hi = self.spans[tensor_id]
                    lo, hi = max(0, lo), min(hi, n_blocks)
                skip = min(self.resumed_from.get(tensor_id, 0), n_blocks)
                skip = max(lo, skip)
                D = None
                if mergeable and rev:
                    D = DeltaIterator(
                        tensor_id, self.plan, self.base_reader,
                        self.expert_readers, coalesce=self.coalesce,
                        windowed=True,
                        coalesce_gap=self.cfg.coalesce_gap_bytes,
                        read_from=skip,
                    )
                task = _TensorTask(tensor_id, spec, n_blocks, mergeable, rev, D)
                if skip and self.resume is not None:
                    # lineage from the dead run, re-seeded from the journal
                    for b, experts in self.resume.coverage(tensor_id):
                        task.touched.append(b)
                        self.coverage_rows.append((tensor_id, b, experts))
                pending.append(("tensor", task, None, None))
                W = self.cfg.window_blocks
                for ws in range(skip, hi, W):
                    if self.stop.is_set():
                        return
                    # cancellation checkpoint: stop issuing new windows;
                    # the error propagates to the consumer, whose abort
                    # path discards everything staged so far
                    _check_cancel(self.cancel, self.plan.plan_id)
                    # prompt failure propagation (see _stage_window)
                    self.wb.raise_if_failed()
                    window = list(range(ws, min(hi, ws + W)))
                    pending.append(
                        ("window", task, window,
                         self.pool.submit(self._stage_window, task, window))
                    )
                    outstanding += 1
                    while outstanding > lookahead:
                        flush_one()
            while pending:
                if self.stop.is_set():
                    return
                flush_one()
            self._put(_PipelineEngine._DONE)
        # broad-except-ok: nothing is swallowed — the error (incl.
        # SimulatedCrash) rides the window queue as an ("error", e) item
        # and is re-raised on the consumer thread, preserving the
        # BaseException-invisibility of simulated crashes to abort paths
        except BaseException as e:  # noqa: BLE001
            self._put(("error", e, None, None))

    def _put(self, item) -> None:
        while not self.stop.is_set():
            try:
                self.q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    # ------------------------------------------------------------- stage 2
    def _compute_window(
        self, task: _TensorTask, window: List[int], base_blocks: Dict,
        pulled: Dict[int, Tuple],
    ) -> None:
        chaos_point("executor:window")
        out: Dict[int, np.ndarray] = {}
        retired: Dict[int, int] = {}
        merged: List[int] = []
        experts_csv: Dict[int, str] = {}
        for b in window:
            got = pulled.get(b)
            if got is None:
                out[b] = base_blocks[b]
                retired[b] = 1
                continue
            deltas, eidxs, eids, _masks = got
            self.realized_expert_blocks += len(eids)
            if eids:
                task.touched.append(b)
                experts_csv[b] = ",".join(eids)
                self.coverage_rows.append((task.tensor_id, b, experts_csv[b]))
            retired[b] = 1 + deltas.shape[0]
            if deltas.shape[0] == 0:
                out[b] = base_blocks[b]
            else:
                merged.append(b)

        if self.kernel_ops is None:
            # per-block numpy apply — bit-identical to the stream path and
            # cache-resident (see the PipelineConfig note)
            for b in merged:
                deltas, eidxs, eids, masks = pulled[b]
                if masks is not None:
                    self.theta["_masks"] = masks
                out[b] = apply_operator(
                    base_blocks[b], deltas, self.plan.op, self.theta
                )
                self.theta.pop("_masks", None)
        elif merged:
            # jitted wrappers: group by (K_sel, width) like the batched
            # path — but windowed, so stacks stay bounded
            groups: Dict[Tuple[int, int], List[int]] = {}
            for b in merged:
                k_sel = pulled[b][0].shape[0]
                groups.setdefault((k_sel, base_blocks[b].size), []).append(b)
            for (k_sel, width), idxs in groups.items():
                x0s = np.stack([base_blocks[b] for b in idxs])
                Ds = np.stack([pulled[b][0] for b in idxs])
                masks = None
                if self.is_dare:
                    masks = np.stack([pulled[b][3] for b in idxs])
                outs = self.kernel_ops.merge_blocks(
                    self.plan.op, np.asarray(x0s, np.float32), Ds,
                    self.theta, masks=masks,
                )
                outs = np.asarray(outs).astype(x0s.dtype)
                for j, b in enumerate(idxs):
                    out[b] = outs[j]

        for b in window:
            self.wb.write_block(task.tensor_id, b, out[b],
                                experts=experts_csv.get(b))
            self.gauge.sub(retired[b])  # base + delta slots retired
        self.windows += 1
        if self.progress is not None:
            self.progress_done += len(window)
            self.progress(self.progress_done, self.progress_total)

    def _finish_tensor(self, task: _TensorTask) -> None:
        self.wb.finish_tensor(task.tensor_id)
        self.touch[task.tensor_id] = task.touched
        if task.D is not None:
            # all of this tensor's windows are computed by the time its
            # finish marker is consumed — retire the adapter Δ-tensors so
            # the residency gauge balances (and the memory is freed)
            self.gauge.sub(task.D.release_adapters())

    def _consume(self) -> None:
        current: Optional[_TensorTask] = None
        while True:
            kind, a, window, payload = self.q.get()
            if kind == "error":
                raise a
            if kind == "done":
                if current is not None:
                    self._finish_tensor(current)
                return
            if kind == "tensor":
                if current is not None:
                    self._finish_tensor(current)
                chaos_point("executor:tensor")
                current = a
                self.wb.begin_tensor(
                    current.tensor_id, current.spec.shape, current.spec.dtype
                )
                continue
            # consumer-side cancellation checkpoint: a cancel that fires
            # while the producer is already drained still aborts here
            _check_cancel(self.cancel, self.plan.plan_id)
            base_blocks, pulled = payload
            self._compute_window(a, window, base_blocks, pulled)

    # ------------------------------------------------------------ lifecycle
    def run(self) -> Tuple[int, Dict]:
        producer = threading.Thread(
            target=self._produce, name="mergepipe-prefetch", daemon=True
        )
        producer.start()
        ok = False
        try:
            self._consume()
            self.wb.flush()
            ok = True
        finally:
            self.stop.set()
            try:  # unblock a producer stuck on a full window queue
                while True:
                    self.q.get_nowait()
            except queue.Empty:
                pass
            producer.join()
            self.pool.shutdown(wait=True)
            self.wb.close(discard=not ok)
        n_experts = len(self.plan.expert_ids)
        return self.realized_expert_blocks, {
            "windows": self.windows,
            "window_blocks": self.cfg.window_blocks,
            "prefetch_windows": self.cfg.prefetch_windows,
            "read_threads": self.cfg.read_threads,
            "kernel": self.cfg.kernel,
            "coalesce_gap_bytes": self.cfg.coalesce_gap_bytes,
            "peak_resident_blocks": self.gauge.peak,
            "resident_bound": self.cfg.max_resident_blocks(n_experts),
            "peak_write_queue_blocks": self.wb.peak_queued,
            "write_queue_bound": self.cfg.write_queue_blocks,
        }
