"""ExecuteMerge — budget-enforced streaming execution (paper §5, Algorithm 2).

The engine enforces a planner-produced plan π:

  * every base block is read and every output block is written — the
    output is always a *complete checkpoint* (C_base, C_out intrinsic);
  * expert blocks are read **iff** selected by π (budget soundness:
    realized expert I/O <= Ĉ_expert(π) <= B);
  * writes are staged, hash-validated, and atomically published as an
    immutable snapshot with full lineage (touch maps + per-block expert
    coverage).

Two compute paths apply the operator:
  ``stream``  — per-block numpy apply (paper-faithful CPU streaming);
  ``batched`` — stacks same-width blocks and calls the jitted kernel
                wrappers in :mod:`repro.kernels.ops` (TPU-native path;
                beyond-paper optimization, bit-identical results are
                asserted in tests).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import blocks as blk
from repro.core.catalog import Catalog
from repro.core.delta_iterator import DeltaIterator
from repro.core.operators import apply_operator, dare_mask
from repro.core.plan import MergePlan
from repro.core.transactions import TransactionManager
from repro.store.iostats import IOStats
from repro.store.snapshot import SnapshotStore


def _ranges_from_indices(idxs: List[int]) -> List[Tuple[int, int]]:
    """Compress sorted block indexes into [start, end) ranges (TouchMap)."""
    if not idxs:
        return []
    runs = []
    start = prev = idxs[0]
    for i in idxs[1:]:
        if i == prev + 1:
            prev = i
            continue
        runs.append((start, prev + 1))
        start = prev = i
    runs.append((start, prev + 1))
    return runs


class MergeResult:
    def __init__(self, sid: str, manifest: Dict, stats: Dict):
        self.sid = sid
        self.manifest = manifest
        self.stats = stats

    def __repr__(self) -> str:  # pragma: no cover
        return f"MergeResult(sid={self.sid!r}, stats={self.stats})"


def execute_merge(
    plan: MergePlan,
    snapshots: SnapshotStore,
    catalog: Catalog,
    sid: Optional[str] = None,
    txn: Optional[TransactionManager] = None,
    coalesce: bool = True,
    compute: str = "stream",
    validate: bool = True,
    enforce_budget: bool = True,
    expert_readers: Optional[Dict[str, object]] = None,
) -> MergeResult:
    """Run Algorithm 2 for plan π and return the committed snapshot.

    ``expert_readers`` optionally injects pre-opened (possibly caching)
    readers keyed by expert id — the API v2 batch session passes shared
    :class:`~repro.store.blockcache.CachingModelReader` instances here so
    one physical scan of an expert block fans out to every job in the
    batch that selected it.  Injected readers are owned by the caller
    and are NOT closed on return.
    """
    t0 = time.time()
    stats: IOStats = snapshots.stats
    expert_read_before = stats.c_expert
    txn = txn or TransactionManager(snapshots, catalog)
    sid = sid or TransactionManager.new_sid()

    if compute == "batched":
        from repro.kernels import ops as kernel_ops  # lazy: jax import
    elif compute != "stream":
        raise ValueError(f"unknown compute mode {compute!r}")
    owns_expert_readers = expert_readers is None
    if expert_readers is not None:
        # validate before any transaction/reader state exists
        missing = [e for e in plan.expert_ids if e not in expert_readers]
        if missing:
            raise KeyError(f"injected expert_readers missing {missing}")

    # -- Transaction and staging -----------------------------------------
    writer = txn.begin()
    touch: Dict[str, List[int]] = {}
    coverage_rows: List[Tuple[str, int, str]] = []

    base_reader = snapshots.models.open_model(plan.base_id)
    if expert_readers is None:
        expert_readers = {
            e: snapshots.models.open_model(e) for e in plan.expert_ids
        }
    theta = dict(plan.theta)
    seed = int(theta.get("seed", 0))
    is_dare = plan.op.lower() == "dare"

    realized_expert_blocks = 0
    try:
        # -- (1) Stream selected blocks under plan π -----------------------
        for tensor_id in plan.tensor_order:
            spec = base_reader.spec(tensor_id)
            writer.begin_tensor(tensor_id, spec.shape, spec.dtype)
            rev = plan.reverse_index(tensor_id)
            mergeable = np.issubdtype(
                np.asarray([], dtype=spec.dtype).dtype, np.floating
            ) or spec["dtype"] in ("bfloat16", "float16", "float32", "float64")
            D = DeltaIterator(
                tensor_id, plan, base_reader, expert_readers, coalesce=coalesce
            )
            n_blocks = blk.num_blocks(spec.nbytes, plan.block_size)
            touched: List[int] = []

            if compute == "batched" and mergeable:
                _run_tensor_batched(
                    kernel_ops, plan, writer, base_reader, D, rev,
                    tensor_id, spec, n_blocks, theta, seed, is_dare,
                    touched, coverage_rows,
                )
                realized_expert_blocks += sum(len(v) for v in rev.values())
            else:
                for b in range(n_blocks):
                    x0 = base_reader.read_block(
                        tensor_id, b, plan.block_size, "base"
                    )
                    if mergeable and b in rev:
                        deltas, eidxs, eids = D.pull(b, x0)
                        realized_expert_blocks += len(eids)
                        if is_dare and len(eids):
                            theta["_masks"] = np.stack(
                                [
                                    dare_mask(
                                        seed, ei, tensor_id, b, x0.size,
                                        float(theta.get("density", 0.5)),
                                    )
                                    for ei in eidxs
                                ]
                            )
                        x = apply_operator(x0, deltas, plan.op, theta)
                        theta.pop("_masks", None)
                        if len(eids):
                            touched.append(b)
                            coverage_rows.append(
                                (tensor_id, b, ",".join(eids))
                            )
                    else:
                        x = x0  # base passthrough (no expert selected)
                    writer.write_block(tensor_id, b, x)
            writer.finish_tensor(tensor_id)
            touch[tensor_id] = touched

        # -- (2) Validate and atomically publish --------------------------
        if validate:
            writer.validate_hashes()

        realized_expert_bytes = stats.c_expert - expert_read_before
        if enforce_budget and plan.budget_b >= 0:
            # Budget soundness (§5.1): realized <= planned <= B, up to the
            # storage layer's accounting granularity (adapters read factor
            # tensors, which are far below the planned block bytes).
            slack = 2 * plan.block_size
            if realized_expert_bytes > plan.c_expert_hat + slack:
                raise RuntimeError(
                    f"budget soundness violated: realized expert bytes "
                    f"{realized_expert_bytes} > planned {plan.c_expert_hat}"
                )

        manifest = {
            "sid": sid,
            "plan_id": plan.plan_id,
            "base_id": plan.base_id,
            "expert_ids": plan.expert_ids,
            "op": plan.op,
            "theta": {k: v for k, v in theta.items() if not k.startswith("_")},
            "budget_b": plan.budget_b,
            "c_expert_hat": plan.c_expert_hat,
            "c_expert_run": realized_expert_bytes,
            "plan_digest": plan.digest(),
            "block_size": plan.block_size,
        }
        sid = txn.atomic_publish(writer, manifest)
        manifest["output_root"] = snapshots.manifest(sid)["output_root"]
        txn.commit_record(sid, manifest)
        catalog.record_touch_map(
            sid, {t: _ranges_from_indices(ix) for t, ix in touch.items()}
        )
        catalog.record_coverage(sid, coverage_rows)
        if plan.parent_sids:
            catalog.record_dag_edges(
                sid,
                [
                    (p, "base" if p == plan.base_id else "expert")
                    for p in plan.parent_sids
                ],
            )
        txn.commit()
    except Exception:
        txn.abort()
        raise
    finally:
        base_reader.close()
        if owns_expert_readers:
            for r in expert_readers.values():
                r.close()

    run_stats = {
        "seconds": time.time() - t0,
        "c_expert_run": realized_expert_bytes,
        "c_expert_hat": plan.c_expert_hat,
        "realized_expert_blocks": realized_expert_blocks,
        "compute": compute,
        "coalesce": coalesce,
    }
    return MergeResult(sid, manifest, run_stats)


def _run_tensor_batched(
    kernel_ops,
    plan: MergePlan,
    writer,
    base_reader,
    D: DeltaIterator,
    rev: Dict[int, List[str]],
    tensor_id: str,
    spec,
    n_blocks: int,
    theta: Dict,
    seed: int,
    is_dare: bool,
    touched: List[int],
    coverage_rows: List[Tuple[str, int, str]],
) -> None:
    """Batched compute path: group blocks by (K_sel, width) and apply the
    jitted kernel once per group.  Physical I/O identical to the stream
    path; only operator application is vectorized."""
    eid_to_idx = {e: i for i, e in enumerate(plan.expert_ids)}
    # gather all blocks first (full tensor streams block-by-block for I/O
    # accounting, then math runs in grouped batches)
    base_blocks: List[np.ndarray] = []
    deltas_per_block: List[Optional[np.ndarray]] = []
    eidxs_per_block: List[List[int]] = []
    for b in range(n_blocks):
        x0 = base_reader.read_block(tensor_id, b, plan.block_size, "base")
        base_blocks.append(x0)
        if b in rev:
            deltas, eidxs, eids = D.pull(b, x0)
            deltas_per_block.append(deltas)
            eidxs_per_block.append(eidxs)
            if len(eids):
                touched.append(b)
                coverage_rows.append((tensor_id, b, ",".join(eids)))
        else:
            deltas_per_block.append(None)
            eidxs_per_block.append([])

    out_blocks: List[Optional[np.ndarray]] = [None] * n_blocks
    groups: Dict[Tuple[int, int], List[int]] = {}
    for b in range(n_blocks):
        d = deltas_per_block[b]
        if d is None or d.shape[0] == 0:
            out_blocks[b] = base_blocks[b]
            continue
        groups.setdefault((d.shape[0], base_blocks[b].size), []).append(b)

    for (k_sel, width), idxs in groups.items():
        x0s = np.stack([np.asarray(base_blocks[b], np.float32) for b in idxs])
        Ds = np.stack([deltas_per_block[b] for b in idxs])  # (nb, k, w)
        masks = None
        if is_dare:
            masks = np.stack(
                [
                    np.stack(
                        [
                            dare_mask(
                                seed, ei, tensor_id, b, width,
                                float(theta.get("density", 0.5)),
                            )
                            for ei in eidxs_per_block[b]
                        ]
                    )
                    for b in idxs
                ]
            )
        outs = kernel_ops.merge_blocks(plan.op, x0s, Ds, theta, masks=masks)
        outs = np.asarray(outs).astype(np.asarray(base_blocks[idxs[0]]).dtype)
        for j, b in enumerate(idxs):
            out_blocks[b] = outs[j]

    for b in range(n_blocks):
        writer.write_block(tensor_id, b, out_blocks[b])
