"""Pallas TPU flash-attention kernel (serving/prefill hot-spot).

Grid ``(B·H, n_q, n_k)`` with the key dim innermost; online-softmax
state (m, l, acc) lives in VMEM scratch and persists across the k-steps
of one (batch·head, q-chunk) row (TPU grids iterate row-major, last dim
fastest).  GQA without materializing repeated KV: the k/v BlockSpec
index maps divide the head index by the group size.  Causal tile skip
via ``pl.when`` — fully-masked tiles are predicated off, recovering the
~2× that the masked-dense formulation wastes (the JAX-level equivalent
is flash_attention(skip_masked_chunks=True); this kernel is the
TPU-native artifact of §Perf H3).

Forward-only (no custom VJP): integrate in inference paths; training
uses the chunked JAX attention (reverse-differentiable).  Validated in
interpret mode against models.attention.flash_attention
(tests/test_kernels.py::test_flash_attention_kernel*).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1.0e30


def _fa_kernel(
    q_ref,    # (1, cq, hd)
    k_ref,    # (1, ck, hd)
    v_ref,    # (1, ck, hdv)
    o_ref,    # (1, cq, hdv)
    m_ref,    # VMEM scratch (cq,)
    l_ref,    # VMEM scratch (cq,)
    acc_ref,  # VMEM scratch (cq, hdv)
    *,
    sk: int,
    cq: int,
    ck: int,
    nk: int,
    causal: bool,
    window: int,
    q_offset: int,
    scale: float,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal/window tile skip (§Perf H3): predicated off entirely when no
    # (q, k) pair in the tile can attend.
    live = jnp.bool_(True)
    if causal:
        live = (kj * ck) <= (q_offset + qi * cq + cq - 1)
    if window > 0:
        live = jnp.logical_and(
            live, (kj * ck + ck - 1) > (q_offset + qi * cq - window)
        )

    @pl.when(live)
    def _tile():
        qpos = q_offset + qi * cq + jax.lax.broadcasted_iota(
            jnp.int32, (cq, ck), 0
        )
        kpos = kj * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
        s = jnp.dot(
            q_ref[0].astype(jnp.float32),
            k_ref[0].astype(jnp.float32).T,
            preferred_element_type=jnp.float32,
        ) * scale                                     # (cq, ck)
        valid = kpos < sk
        if causal:
            valid = valid & (qpos >= kpos)
        if window > 0:
            valid = valid & (qpos - kpos < window)
        s = jnp.where(valid, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * valid.astype(jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,  # (B, Sk, Hkv, hdv)
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    cq: int = 256,
    ck: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    _, sk, hkv, _ = k.shape
    hdv = v.shape[-1]
    g = h // hkv
    scale = 1.0 / float(hd) ** 0.5

    cq = min(cq, sq)
    ck = min(ck, sk)
    pad_q = (-sq) % cq
    pad_k = (-sk) % ck
    # collapse (B, H) into the grid's leading axis
    qg = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    qg = qg.transpose(0, 2, 1, 3).reshape(b * h, sq + pad_q, hd)
    kg = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kg = kg.transpose(0, 2, 1, 3).reshape(b * hkv, sk + pad_k, hd)
    vg = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vg = vg.transpose(0, 2, 1, 3).reshape(b * hkv, sk + pad_k, hdv)
    nq = (sq + pad_q) // cq
    nk = (sk + pad_k) // ck

    kernel = functools.partial(
        _fa_kernel, sk=sk, cq=cq, ck=ck, nk=nk,
        causal=causal, window=window, q_offset=q_offset, scale=scale,
    )
    # k/v head index = query head // group size (GQA without repeats);
    # bind g via default arg so the index_map stays a plain function.
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, cq, hd), lambda i, qi, kj: (i, qi, 0)),
            pl.BlockSpec((1, ck, hd), lambda i, qi, kj, g=g: (i // g, kj, 0)),
            pl.BlockSpec((1, ck, hdv), lambda i, qi, kj, g=g: (i // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, cq, hdv), lambda i, qi, kj: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pad_q, hdv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq,), jnp.float32),
            pltpu.VMEM((cq,), jnp.float32),
            pltpu.VMEM((cq, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    out = out.reshape(b, h, sq + pad_q, hdv).transpose(0, 2, 1, 3)
    return out[:, :sq]
