"""Pallas TPU kernels for MergePipe's compute hot-spots.

    merge_block.py  — fused blockwise AVG/TA/TIES/DARE + ANALYZE sketch
                      (pl.pallas_call with explicit VMEM BlockSpec tiling)
    ops.py          — jitted wrappers; TPU->Pallas, CPU->XLA-fused jnp ref
    ref.py          — pure-jnp oracles (allclose target for every kernel)

Validated on CPU via interpret=True (tests/test_kernels.py sweeps
shapes × dtypes × K).  TPU v5e is the deployment target.
"""
from repro.kernels import merge_block, ops, ref

__all__ = ["merge_block", "ops", "ref"]
