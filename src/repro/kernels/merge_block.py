"""Pallas TPU kernels for blockwise merge operators.

TPU-native adaptation (DESIGN.md §6): the paper's hot loop is
``ApplyOperator(x0, {Δi})`` over streamed blocks on a CPU; on TPU the same
work is a VPU elementwise-fusion problem.  We tile the *block batch*
``(NB, W)`` into VMEM tiles and keep all K expert delta tiles resident,
fusing trim-mask -> sign-election -> disjoint-mean -> λ-scale (TIES),
mask -> rescale -> sum (DARE), and the linear ops (AVG / TA) into single
kernels — one HBM round-trip per operand instead of one per arithmetic op.

Tiling: grid is (NB/TB, W/TW) with TB=8 (sublane) and TW=1024 (8×128
lanes), K resident in VMEM.  VMEM per grid step ≈ (K+2)·TB·TW·4B
≈ (K+2)·32 KiB — comfortably inside the ~16 MiB VMEM for K ≤ 64.
Merging has arithmetic intensity < 1 FLOP/byte, so the kernels are
HBM-bandwidth-bound by construction; the win is the fusion, not FLOPs.

TIES trim thresholds (a per-row top-k) are computed *outside* the kernel
by XLA's optimized sort (see ops.py) and passed in as a (NB, K) operand —
sorting inside a VPU kernel would waste the fused pass.

The container is CPU-only: kernels are validated with ``interpret=True``
(kernel body executed in Python) against :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU-aligned tile: 8 sublanes × 128 lanes; TW a multiple of 128.
TILE_NB = 8
TILE_W = 1024


def _grid(nb: int, w: int, tb: int, tw: int):
    return (pl.cdiv(nb, tb), pl.cdiv(w, tw))


# ----------------------------------------------------------------- AVG / TA
def _linear_kernel(x0_ref, d_ref, o_ref, *, coeff: float):
    """out = x0 + coeff * Σ_k Δ_k   (AVG: coeff=1/(K+1), TA: coeff=λ)."""
    acc = jnp.sum(d_ref[...], axis=1)  # (TB, TW), K reduced in VMEM
    o_ref[...] = x0_ref[...] + coeff * acc


def linear_merge_pallas(
    x0: jnp.ndarray,
    D: jnp.ndarray,
    coeff: float,
    tb: int = TILE_NB,
    tw: int = TILE_W,
    interpret: bool = False,
) -> jnp.ndarray:
    nb, k, w = D.shape
    return pl.pallas_call(
        functools.partial(_linear_kernel, coeff=coeff),
        grid=_grid(nb, w, tb, tw),
        in_specs=[
            pl.BlockSpec((tb, tw), lambda i, j: (i, j)),
            pl.BlockSpec((tb, k, tw), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((tb, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, w), x0.dtype),
        interpret=interpret,
    )(x0, D)


# ----------------------------------------------------------------------- TIES
def _ties_kernel(x0_ref, d_ref, t_ref, o_ref, *, lam: float):
    d = d_ref[...]                       # (TB, K, TW)
    thresh = t_ref[...][:, :, None]      # (TB, K, 1)
    mask = jnp.abs(d) >= thresh
    dt = jnp.where(mask, d, 0.0)
    elected = jnp.sign(jnp.sum(dt, axis=1))              # (TB, TW)
    agree = (jnp.sign(dt) == elected[:, None, :]) & mask
    agree = agree & (elected != 0)[:, None, :]
    num = jnp.sum(jnp.where(agree, dt, 0.0), axis=1)
    cnt = jnp.sum(agree.astype(jnp.float32), axis=1)
    o_ref[...] = x0_ref[...] + lam * num / jnp.maximum(cnt, 1.0)


def ties_merge_pallas(
    x0: jnp.ndarray,
    D: jnp.ndarray,
    thresh: jnp.ndarray,
    lam: float = 1.0,
    tb: int = TILE_NB,
    tw: int = TILE_W,
    interpret: bool = False,
) -> jnp.ndarray:
    nb, k, w = D.shape
    return pl.pallas_call(
        functools.partial(_ties_kernel, lam=lam),
        grid=_grid(nb, w, tb, tw),
        in_specs=[
            pl.BlockSpec((tb, tw), lambda i, j: (i, j)),
            pl.BlockSpec((tb, k, tw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tb, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, w), x0.dtype),
        interpret=interpret,
    )(x0, D, thresh)


# ----------------------------------------------------------------------- DARE
def _dare_kernel(x0_ref, d_ref, m_ref, o_ref, *, inv_density: float, lam: float):
    d = d_ref[...]
    m = m_ref[...].astype(jnp.float32)   # (TB, K, TW) keep mask
    acc = jnp.sum(d * m, axis=1) * inv_density
    o_ref[...] = x0_ref[...] + lam * acc


def dare_merge_pallas(
    x0: jnp.ndarray,
    D: jnp.ndarray,
    masks: jnp.ndarray,
    density: float = 0.5,
    lam: float = 1.0,
    tb: int = TILE_NB,
    tw: int = TILE_W,
    interpret: bool = False,
) -> jnp.ndarray:
    nb, k, w = D.shape
    return pl.pallas_call(
        functools.partial(_dare_kernel, inv_density=1.0 / density, lam=lam),
        grid=_grid(nb, w, tb, tw),
        in_specs=[
            pl.BlockSpec((tb, tw), lambda i, j: (i, j)),
            pl.BlockSpec((tb, k, tw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tb, k, tw), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((tb, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, w), x0.dtype),
        interpret=interpret,
    )(x0, D, masks.astype(jnp.int8))


# ------------------------------------------------------------ ANALYZE sketch
def _sketch_kernel(x_ref, o_ref):
    """Per-block partial stats: Σx², max|x|, Σx over the width tile.
    Width-tile partials are accumulated by the caller (associative)."""
    x = x_ref[...]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sq = jnp.sum(x * x, axis=1)
    mx = jnp.max(jnp.abs(x), axis=1)
    sm = jnp.sum(x, axis=1)
    prev = o_ref[...]
    o_ref[...] = jnp.stack(
        [prev[:, 0] + sq, jnp.maximum(prev[:, 1], mx), prev[:, 2] + sm], axis=1
    )


def sketch_blocks_pallas(
    x: jnp.ndarray,
    tb: int = TILE_NB,
    tw: int = TILE_W,
    interpret: bool = False,
) -> jnp.ndarray:
    """(NB, W) -> (NB, 3) stats [Σx², max|x|, Σx] for ANALYZE on-device."""
    nb, w = x.shape
    return pl.pallas_call(
        _sketch_kernel,
        grid=_grid(nb, w, tb, tw),
        in_specs=[pl.BlockSpec((tb, tw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tb, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 3), jnp.float32),
        interpret=interpret,
    )(x)
