"""Jitted wrappers + dispatch for the merge kernels.

``merge_blocks(op, x0s, Ds, theta, masks=None)`` is the single entry used
by the executor's batched path and the distributed merge step.  Backend
selection:

    * TPU          -> Pallas kernels (compiled)
    * CPU/other    -> pure-jnp reference (XLA-fused; Pallas interpret mode
                      is Python-per-tile and only used for validation)
    * REPRO_FORCE_PALLAS=1 -> Pallas with interpret fallback (tests)

Inputs may be any float dtype; math runs in float32 and the result is
cast back (matching the streaming executor's numpy semantics).
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import merge_block as mb
from repro.kernels import ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except (RuntimeError, IndexError):  # pragma: no cover — no backend
        return False


def _force_pallas() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS", "0") == "1"


def use_pallas() -> bool:
    return _on_tpu() or _force_pallas()


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pallas_padded(fn, x0, D, *extras, tb=mb.TILE_NB, tw=mb.TILE_W, **kw):
    """Pad (NB, W) to tile multiples, run the kernel, slice back."""
    nb, w = x0.shape
    tw = min(tw, max(128, ((w + 127) // 128) * 128))
    x0p = _pad_to(_pad_to(x0, tb, 0), tw, 1)
    Dp = _pad_to(_pad_to(D, tb, 0), tw, 2)
    extras_p = []
    for e in extras:
        e = _pad_to(e, tb, 0)
        if e.ndim == 3:
            e = _pad_to(e, tw, 2)
        extras_p.append(e)
    out = fn(x0p, Dp, *extras_p, tb=tb, tw=tw, interpret=not _on_tpu(), **kw)
    return out[:nb, :w]


# --------------------------------------------------------------- public API
def merge_blocks(
    op: str,
    x0s,
    Ds,
    theta: Dict,
    masks=None,
) -> np.ndarray:
    """Apply operator ``op`` to a batch of blocks.

    x0s (NB, W) float; Ds (NB, K, W); masks (NB, K, W) for DARE.
    Returns float32 ndarray (NB, W).
    """
    x0 = jnp.asarray(x0s, jnp.float32)
    D = jnp.asarray(Ds, jnp.float32)
    lam = float(theta.get("lam", 1.0))
    op = op.lower()
    pallas = use_pallas()

    if op == "avg":
        k = D.shape[1]
        if pallas:
            out = _pallas_padded(mb.linear_merge_pallas, x0, D, coeff=1.0 / (k + 1))
        else:
            out = _avg_jit(x0, D)
    elif op == "ta":
        if pallas:
            out = _pallas_padded(mb.linear_merge_pallas, x0, D, coeff=lam)
        else:
            out = _ta_jit(x0, D, lam)
    elif op == "ties":
        trim = float(theta.get("trim_frac", 0.2))
        thresh = _ties_thresh_jit(D, trim)
        if pallas:
            out = _pallas_padded(mb.ties_merge_pallas, x0, D, thresh, lam=lam)
        else:
            out = _ties_apply_jit(x0, D, thresh, lam)
    elif op == "dare":
        if masks is None:
            raise ValueError("dare requires masks")
        m = jnp.asarray(masks)
        density = float(theta.get("density", 0.5))
        if pallas:
            out = _pallas_padded(
                mb.dare_merge_pallas, x0, D, m, density=density, lam=lam
            )
        else:
            out = _dare_jit(x0, D, m, density, lam)
    else:
        raise KeyError(f"unknown operator {op!r}")
    return np.asarray(out)


# ------------------------------------------------------------ jitted refs
@jax.jit
def _avg_jit(x0, D):
    return ref.avg_ref(x0, D)


@functools.partial(jax.jit, static_argnums=(2,))
def _ta_jit(x0, D, lam):
    return ref.ta_ref(x0, D, lam)


@functools.partial(jax.jit, static_argnums=(1,))
def _ties_thresh_jit(D, trim):
    return ref.ties_thresholds(D, trim)


@functools.partial(jax.jit, static_argnums=(3,))
def _ties_apply_jit(x0, D, thresh, lam):
    return ref.ties_apply_ref(x0, D, thresh, lam)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _dare_jit(x0, D, m, density, lam):
    return ref.dare_ref(x0, D, m, density, lam)


def sketch_blocks(x) -> np.ndarray:
    """(NB, W) -> (NB, 3) [l2, absmax, mean] (ANALYZE on-device path)."""
    xj = jnp.asarray(x, jnp.float32)
    if use_pallas():
        nb, w = xj.shape
        tw = min(mb.TILE_W, max(128, ((w + 127) // 128) * 128))
        xp = _pad_to(_pad_to(xj, mb.TILE_NB, 0), tw, 1)
        stats = mb.sketch_blocks_pallas(
            xp, tb=mb.TILE_NB, tw=tw, interpret=not _on_tpu()
        )[:nb]
    else:
        stats = _sketch_jit(xj)
    sq, mx, sm = stats[:, 0], stats[:, 1], stats[:, 2]
    w = x.shape[1]
    return np.stack(
        [np.sqrt(np.asarray(sq)), np.asarray(mx), np.asarray(sm) / w], axis=1
    )


@jax.jit
def _sketch_jit(x):
    return jnp.stack(
        [jnp.sum(x * x, axis=1), jnp.max(jnp.abs(x), axis=1), jnp.sum(x, axis=1)],
        axis=1,
    )
