"""Pure-jnp oracles for the merge kernels.

Shapes (the executor's batched layout):
    x0     (NB, W)        base blocks, float32
    D      (NB, K, W)     stacked expert deltas, float32
    masks  (NB, K, W)     DARE keep masks (bool)
    thresh (NB, K)        TIES per-(block, expert) trim thresholds

These mirror :mod:`repro.core.operators` bit-for-bit (same trim rule,
same election rule) and serve as the allclose oracle for the Pallas
kernels in :mod:`repro.kernels.merge_block`.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ties_thresholds(D: jnp.ndarray, trim_frac: float) -> jnp.ndarray:
    """keep-th largest |Δ| per (block, expert) row; keep = round(ρ·W)."""
    nb, k, w = D.shape
    keep = max(1, int(round(trim_frac * w)))
    if keep >= w:
        return jnp.full((nb, k), -jnp.inf, dtype=jnp.float32)
    absd = jnp.abs(D)
    # sorted ascending, element [w - keep] == keep-th largest
    srt = jnp.sort(absd, axis=-1)
    return srt[..., w - keep]


def avg_ref(x0: jnp.ndarray, D: jnp.ndarray) -> jnp.ndarray:
    k = D.shape[1]
    return x0 + D.sum(axis=1) / (k + 1)


def ta_ref(x0: jnp.ndarray, D: jnp.ndarray, lam: float = 1.0) -> jnp.ndarray:
    return x0 + lam * D.sum(axis=1)


def ties_apply_ref(
    x0: jnp.ndarray, D: jnp.ndarray, thresh: jnp.ndarray, lam: float = 1.0
) -> jnp.ndarray:
    """Trim (by precomputed thresholds) -> elect sign -> sign-matched mean."""
    mask = jnp.abs(D) >= thresh[..., None]
    Dt = jnp.where(mask, D, 0.0)
    elected = jnp.sign(Dt.sum(axis=1))  # (NB, W)
    agree = (jnp.sign(Dt) == elected[:, None, :]) & mask & (elected != 0)[:, None, :]
    num = jnp.where(agree, Dt, 0.0).sum(axis=1)
    cnt = agree.sum(axis=1)
    return x0 + lam * num / jnp.maximum(cnt, 1)


def ties_ref(
    x0: jnp.ndarray, D: jnp.ndarray, trim_frac: float = 0.2, lam: float = 1.0
) -> jnp.ndarray:
    return ties_apply_ref(x0, D, ties_thresholds(D, trim_frac), lam)


def dare_ref(
    x0: jnp.ndarray,
    D: jnp.ndarray,
    masks: jnp.ndarray,
    density: float = 0.5,
    lam: float = 1.0,
) -> jnp.ndarray:
    rescaled = jnp.where(masks, D, 0.0) / density
    return x0 + lam * rescaled.sum(axis=1)
