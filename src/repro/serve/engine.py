"""Batched serving engine: continuous prefill + decode over a model zoo
member (used by examples/serve_merged.py and the serving tests).

Minimal-but-real structure: a request queue, a fixed decode batch with
slot recycling, greedy/temperature sampling, and jitted prefill/decode
steps.  The decode cache is allocated once at engine start (static
shapes => one compilation), requests claim slots and free them at EOS.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        model,
        params,
        batch_slots: int = 4,
        max_len: int = 256,
        rng_seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.cfg = model.cfg
        self._decode = jax.jit(model.decode_step)
        self._key = jax.random.PRNGKey(rng_seed)
        # one shared cache batch; slot i belongs to at most one request
        self.cache = model.init_cache(batch_slots, max_len)
        self._slot_req: List[Optional[Request]] = [None] * batch_slots

    # -- single-request prefill (per-slot caches are merged by batch dim) --
    def _prefill_slot(self, slot: int, req: Request) -> int:
        """Prefill one request and splice its cache row into the engine
        cache at ``slot``.  The batch axis of each cache leaf is detected
        structurally (engine dim == slots where the single-request dim is
        1); other dims are zero-padded up to the engine shapes."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = self.model.prefill(self.params, toks)
        # first generated token comes from the prefill logits
        req.out_tokens.append(self._sample(req, np.asarray(logits[0, 0])))
        new_cache = {}
        for k, big in self.cache.items():
            if k == "len":
                new_cache[k] = cache1[k]
                continue
            small = cache1[k]
            batch_ax = tuple(
                big.shape[ax] == self.slots and small.shape[ax] == 1
                for ax in range(big.ndim)
            )
            pads = [
                (0, (1 if batch_ax[ax] else big.shape[ax]) - small.shape[ax])
                for ax in range(big.ndim)
            ]
            small = jnp.pad(small, pads)
            start = tuple(slot if a else 0 for a in batch_ax)
            new_cache[k] = jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), start
            )
        self.cache = new_cache
        return int(cache1["len"])

    def _sample(self, req: Request, logits: np.ndarray) -> int:
        if req.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            return int(jax.random.categorical(
                sub, jnp.asarray(logits) / req.temperature
            ))
        return int(np.argmax(logits))

    def submit(self, req: Request) -> bool:
        for slot, owner in enumerate(self._slot_req):
            if owner is None:
                self._slot_req[slot] = req
                req._slot = slot  # type: ignore[attr-defined]
                req._len = self._prefill_slot(slot, req)  # type: ignore
                return True
        return False

    def step(self) -> None:
        """One decode step for every active slot (batched)."""
        active = [r for r in self._slot_req if r is not None]
        if not active:
            return
        # engine caches share a scalar len; per-slot lens tracked host-side.
        # For simplicity all active requests advance together from the max
        # len (correctness: shorter prompts were left-padded into the cache).
        cur = max(getattr(r, "_len") for r in active)
        tok = np.zeros((self.slots, 1), np.int32)
        for r in active:
            tok[getattr(r, "_slot"), 0] = r.out_tokens[-1]
        cache = dict(self.cache)
        cache["len"] = jnp.asarray(cur, jnp.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tok), cache
        )
        logits = np.asarray(logits[:, 0], np.float32)
        for r in active:
            slot = getattr(r, "_slot")
            r.out_tokens.append(self._sample(r, logits[slot]))
            setattr(r, "_len", cur + 1)
            if len(r.out_tokens) >= r.max_new_tokens or cur + 1 >= self.max_len:
                r.done = True
                self._slot_req[slot] = None

    def run(self, requests: List[Request], max_steps: int = 10_000) -> None:
        pending = list(requests)
        steps = 0
        while (pending or any(self._slot_req)) and steps < max_steps:
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
