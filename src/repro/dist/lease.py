"""Shard leases and distributed-execution options.

A :class:`ShardLease` is the complete, self-contained work order the
coordinator hands a worker: which shard of which exec sid, the global
block spans to merge, the per-shard byte budget, where to stage the
region, and which journal namespace to append progress into.  It
round-trips through JSON so the process transport can pass it by file —
the same document a future RPC transport would put on the wire.

Leases are versioned by ``attempt``: when a worker dies its lease
expires and the shard is re-issued at ``attempt + 1`` to a survivor,
which resumes from the shard journal's high-water mark.  The journal
namespace is per-shard (not per-attempt) precisely so the successor can
see its predecessor's progress.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

TRANSPORTS = ("process", "inline")
KERNELS = ("numpy", "jax", "mesh")


@dataclasses.dataclass(frozen=True)
class DistOptions:
    """Knobs for ``execution="sharded"`` (see docs/DISTRIBUTED.md).

    ``transport="process"`` launches each worker as a separate Python
    process (the CI-friendly stand-in for remote hosts); ``"inline"``
    runs workers synchronously in the coordinator process — useful for
    deterministic tests that need the dead attempt's partial stats.
    ``kernel`` selects the worker's compute path: the bit-identical
    ``"numpy"`` stream kernel, the jitted ``"jax"`` block kernel, or
    ``"mesh"`` — the packed whole-tensor device path of
    ``core.distributed.build_merge_step`` (tolerance-level on TIES tail
    blocks; forces tensor-aligned shard cuts).
    """

    n_workers: int = 2
    transport: str = "process"
    kernel: str = "numpy"
    max_lease_attempts: int = 3
    journal_sync_every: Optional[int] = None
    heartbeat_s: float = 0.2
    #: chaos hand-off to workers: {"point": ..., "skip": int, "shard": int,
    #: "mode"?: ...} — armed only on the target shard's FIRST attempt so
    #: recovery tests kill exactly one worker once
    chaos: Optional[Dict] = None

    def validate(self) -> None:
        if self.n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        if self.transport not in TRANSPORTS:
            raise ValueError(
                "unknown transport %r (expected one of %s)"
                % (self.transport, ", ".join(TRANSPORTS)))
        if self.kernel not in KERNELS:
            raise ValueError(
                "unknown worker kernel %r (expected one of %s)"
                % (self.kernel, ", ".join(KERNELS)))
        if self.max_lease_attempts < 1:
            raise ValueError("max_lease_attempts must be >= 1")


@dataclasses.dataclass
class ShardLease:
    """One shard's work order (JSON round-trippable)."""

    shard: int
    sid: str
    attempt: int
    #: per-shard expert byte budget (partitioner's extent-once cost plus
    #: cross-shard extent re-reads); the worker widens it exactly the
    #: way execute_merge widens the plan budget
    budget: int
    #: [(tensor, lo, hi)] global half-open block spans, plan order
    spans: List[Tuple[str, int, int]]
    #: full plan payload (MergePlan.to_payload) — workers rebuild the
    #: identical plan so selections, DARE seeds, digests all agree
    plan: Dict
    block_size: int
    shard_dir: str
    journal_path: str
    coalesce: bool = True
    #: False, True, or a {"flat","remote","packed"} policy dict
    verify: object = True
    kernel: str = "numpy"
    #: dataclasses.asdict(PipelineConfig) or None for defaults
    pipeline: Optional[Dict] = None
    journal_sync_every: Optional[int] = None
    chaos: Optional[Dict] = None

    def to_doc(self) -> Dict:
        d = dataclasses.asdict(self)
        d["spans"] = [[t, int(lo), int(hi)] for t, lo, hi in self.spans]
        return d

    @classmethod
    def from_doc(cls, doc: Dict) -> "ShardLease":
        d = dict(doc)
        d["spans"] = [(t, int(lo), int(hi)) for t, lo, hi in d["spans"]]
        return cls(**d)

    def span_map(self) -> Dict[str, Tuple[int, int]]:
        return {t: (lo, hi) for t, lo, hi in self.spans}

    def write(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_doc(), f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # chaos-ok: worker-death points live in dist/worker.py

    @classmethod
    def read(cls, path: str) -> "ShardLease":
        with open(path) as f:
            return cls.from_doc(json.load(f))
