"""Shard-side staged output regions.

A worker stages its slice of the output as per-tensor *region files* —
the same streaming format, hashes, and progress journal as the real
:class:`~repro.store.snapshot.StagingWriter`, just rooted in a per-shard
directory and indexed by LOCAL block (``global - span_lo``).  Keeping the
journal local-indexed means ``parse_journal``/``build_resume_state``
work on shard journals verbatim: a successor worker re-validates the
region prefix exactly the way service recovery re-validates a dead
run's staging.

Region bytes are deliberately billed to the ``other`` IOStats category
(writes here, reads at coordinator splice time): the canonical ``out``
bytes are recorded once, by the coordinator's real StagingWriter, so
per-category parity with single-process execution holds and the shard
overhead is visible instead of laundered into C_out.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.store.iostats import IOStats
from repro.store.journal import ProgressJournal, ResumeState
from repro.store.snapshot import StagingWriter
from repro.testing.chaos import chaos_point


class _RegionStats:
    """Remaps the wrapped StagingWriter's billing (``out`` writes,
    ``meta`` validation reads) onto ``other`` — region I/O is shard
    overhead, not canonical output volume."""

    def __init__(self, stats: IOStats):
        self._stats = stats

    def record_write(self, category: str, nbytes: int) -> None:
        self._stats.record_write("other", nbytes)

    def record_read(self, category: str, nbytes: int) -> None:
        self._stats.record_read("other", nbytes)


class ShardRegionWriter:
    """StagingWriter facade taking GLOBAL block indices over a lease's
    spans.  Implements the writer protocol the pipelined engine's
    write-behind stage expects (begin_tensor / write_block /
    finish_tensor), so the engine runs unmodified over a shard."""

    def __init__(
        self,
        shard_dir: str,
        spans: Dict[str, Tuple[int, int]],
        stats: IOStats,
        journal: Optional[ProgressJournal] = None,
        resume: Optional[ResumeState] = None,
    ):
        self.spans = spans
        self.dir = shard_dir
        self.inner = StagingWriter(
            shard_dir, _RegionStats(stats), journal=journal, resume=resume
        )

    def begin_tensor(self, tensor_id: str, shape, dtype) -> None:
        if tensor_id not in self.spans:
            raise RuntimeError(
                "tensor %r is outside this shard's lease" % tensor_id)
        self.inner.begin_tensor(tensor_id, shape, dtype)

    def write_block(
        self,
        tensor_id: str,
        block_idx: int,
        block: np.ndarray,
        experts: Optional[str] = None,
    ) -> None:
        chaos_point("worker:block")
        lo, hi = self.spans[tensor_id]
        if not (lo <= block_idx < hi):
            raise RuntimeError(
                "block %d of %r outside shard span [%d, %d)"
                % (block_idx, tensor_id, lo, hi))
        self.inner.write_block(tensor_id, block_idx - lo, block,
                               experts=experts)

    def finish_tensor(self, tensor_id: str) -> None:
        self.inner.finish_tensor(tensor_id)

    def validate_hashes(self) -> None:
        self.inner.validate_hashes()

    def abort(self) -> None:
        self.inner.abort()

    def detach(self) -> None:
        self.inner.detach()

    def region_manifest(self) -> List[Dict]:
        """[{tensor, lo, hi, file, nbytes, hash, shape, dtype}] for the
        coordinator splice — ``file`` is relative to the shard dir and
        ``hash`` is the streaming blake2b-16 over the region bytes."""
        out = []
        for tensor_id, spec in self.inner.specs.items():
            lo, hi = self.spans[tensor_id]
            out.append({
                "tensor": tensor_id,
                "lo": lo,
                "hi": hi,
                "file": spec["file"],
                "nbytes": spec["nbytes"],
                "hash": spec["hash"],
                "shape": spec["shape"],
                "dtype": spec["dtype"],
            })
        return out
