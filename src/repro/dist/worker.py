"""Shard worker — one lease in, one staged region + result doc out.

A worker is the distributed analogue of one ``execute_merge`` call,
minus the transaction: it opens the workspace substrate read-only-ish
(fresh :class:`IOStats`, no recovery, no TransactionManager), rebuilds
the exact plan from the lease payload, and runs the UNMODIFIED pipelined
engine over its global block spans — flat, packed, and tiered/remote
readers all compose with selection slicing, verify-on-read attaches per
reader exactly as in single-process execution, and per-block progress
journals into the shard's own :class:`ProgressJournal` namespace.

Crash semantics mirror the single-process engine: a
:class:`SimulatedCrash` (or a real worker death) leaves the staged
region and shard journal on disk; a successor worker holding the
re-issued lease validates the journaled prefix with the standard
``parse_journal``/``build_resume_state`` machinery (shard journals are
local-indexed, so they parse verbatim) and resumes at the high-water
block, billing the skipped volume as refunded residuals.

The worker enforces its per-shard byte budget the way ``execute_merge``
enforces the plan budget: lease budget plus two blocks of accounting
granularity plus honestly-recorded widenings (packed extent re-reads
under memory caps, disk-cache evict refetches, read-repair traffic).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import blocks as blk
from repro.core.catalog import Catalog
from repro.core.delta_iterator import DeltaIterator
from repro.core.executor import (
    PipelineConfig,
    _is_mergeable,
    _PipelineEngine,
    _packed_layouts_behind,
    _tiered_readers_behind,
)
from repro.core.plan import MergePlan
from repro.dist.lease import ShardLease
from repro.dist.region import ShardRegionWriter
from repro.store.integrity import VerifyPolicy, attach_verifier
from repro.store.iostats import IOStats
from repro.store.journal import (
    ProgressJournal,
    ResumeState,
    build_resume_state,
    parse_journal,
)
from repro.store.snapshot import SnapshotStore
from repro.testing import chaos
from repro.testing.chaos import chaos_point


class _GlobalResumeView:
    """Adapter presenting a shard journal's LOCAL-indexed resume state
    to the engine, which thinks in GLOBAL block indices.  The engine
    only reads ``.completed`` and ``.coverage()`` — the region writer
    consumes the underlying local state directly."""

    def __init__(self, rs: ResumeState, spans: Dict[str, Tuple[int, int]]):
        self._rs = rs
        self._spans = spans
        self.completed = {
            t: spans[t][0] + n
            for t, n in rs.completed.items()
            if t in spans
        }

    def coverage(self, tensor_id: str) -> List[Tuple[int, str]]:
        lo = self._spans[tensor_id][0]
        return [(lo + b, experts) for b, experts in self._rs.coverage(tensor_id)]


def _coerce_verify(verify) -> object:
    if isinstance(verify, dict):
        return VerifyPolicy(**verify)
    return verify


def run_worker(
    workspace: str,
    lease: ShardLease,
    result_path: Optional[str] = None,
    stats: Optional[IOStats] = None,
) -> Dict:
    """Execute one shard lease; returns (and optionally writes) the
    result doc the coordinator splices from.  Raises
    :class:`~repro.testing.chaos.SimulatedCrash` straight through —
    staged region + shard journal survive for the successor."""
    armed = False
    if lease.chaos:
        chaos.arm(lease.chaos["point"], int(lease.chaos.get("skip", 0)))
        armed = True
    try:
        chaos_point("worker:lease")
        doc = _run(workspace, lease, stats if stats is not None else IOStats())
        # the "commit" of a worker is its result doc becoming visible —
        # a death here loses the attempt exactly like a mid-block death
        chaos_point("worker:commit")
        if result_path is not None:
            _write_json(result_path, doc)
        return doc
    finally:
        if armed:
            chaos.disarm()


def _write_json(path: str, doc: Dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # chaos-ok: worker:commit fires before this write


def _run(workspace: str, lease: ShardLease, stats: IOStats) -> Dict:
    t0 = time.time()
    snapshots = SnapshotStore(workspace, stats)
    catalog = Catalog(os.path.join(workspace, "catalog.sqlite"), stats)
    plan = MergePlan.from_payload(lease.plan)
    spans = lease.span_map()
    expert_read_before = stats.c_expert

    # -- shard-journal resume (predecessor's high-water mark) -----------
    resume = None
    parsed = parse_journal(lease.journal_path, stats)
    if parsed is not None:
        if parsed.plan_digest != plan.digest() or lease.kernel == "mesh":
            # plan drift (worthless blocks) or the whole-tensor mesh
            # path (recomputes its spans wholesale) — start fresh
            shutil.rmtree(parsed.staging_dir, ignore_errors=True)
            try:
                os.unlink(lease.journal_path)
            except FileNotFoundError:
                pass
        else:
            resume = build_resume_state(parsed, stats)

    os.makedirs(os.path.dirname(lease.journal_path), exist_ok=True)
    journal = ProgressJournal(
        lease.journal_path, stats,
        sync_every=(lease.journal_sync_every
                    if lease.journal_sync_every is not None
                    else SnapshotStore.journal_sync_every),
    )
    journal.begin(
        "%s#shard%d" % (lease.sid, lease.shard), plan.plan_id, plan.digest(),
        lease.shard_dir, lease.block_size, attempt=lease.attempt,
    )
    writer = ShardRegionWriter(
        lease.shard_dir, spans, stats, journal=journal, resume=resume,
    )

    resume_view = None
    resumed_blocks = 0
    if resume is not None:
        resume_view = _GlobalResumeView(resume, spans)
        # refunded residuals: the predecessor already paid for the
        # validated prefix — record the skipped logical volume so crash
        # + resume provably covers each selected byte once
        for t, tr in resume.tensors.items():
            if t not in spans or not tr.n_validated:
                continue
            lo, _hi = spans[t]
            resumed_blocks += tr.n_validated
            stats.record_skip("base", tr.validated_nbytes)
            stats.record_skip("out", tr.validated_nbytes)
            rev = plan.reverse_index(t)
            skipped = 0
            for bl in range(tr.n_validated):
                skipped += len(rev.get(lo + bl, ())) * tr.block_nbytes[bl]
            stats.record_skip("expert", skipped)

    # -- readers: exactly the owned path of execute_merge ---------------
    base_reader = snapshots.models.open_model(plan.base_id)
    packed_layout = None
    if getattr(plan, "layout_id", None):
        packed_layout = snapshots.packed.open_layout(plan.layout_id)
        expert_readers = {
            e: packed_layout.open_member(e) for e in plan.expert_ids
        }
    else:
        expert_readers = {
            e: snapshots.models.open_model(e) for e in plan.expert_ids
        }
    merge_layouts = (
        [packed_layout] if packed_layout is not None
        else _packed_layouts_behind(expert_readers)
    )
    reread_before = sum(l.reread_bytes for l in merge_layouts)
    tiered_readers = _tiered_readers_behind(
        [base_reader, *expert_readers.values()]
    )
    evict_refetch_before = sum(r.evict_refetch_bytes for r in tiered_readers)
    verify_policy = VerifyPolicy.coerce(_coerce_verify(lease.verify))
    verifiers = []
    for mid, r in [(plan.base_id, base_reader), *expert_readers.items()]:
        v = attach_verifier(r, catalog, mid, plan.block_size, verify_policy)
        if v is not None:
            verifiers.append(v)
    repair_before = sum(
        getattr(r, "repair_bytes", 0) for r in tiered_readers
    ) + sum(getattr(l, "repair_bytes", 0) for l in merge_layouts)

    cfg = (
        PipelineConfig(**lease.pipeline) if lease.pipeline is not None
        else (PipelineConfig.for_remote()
              if any(getattr(r, "prefers_deep_prefetch", False)
                     for r in tiered_readers)
              else PipelineConfig())
    )
    kernel_ops = None
    if lease.kernel == "jax":
        from repro.kernels import ops as kernel_ops  # lazy: jax import
        cfg = dataclasses.replace(cfg, kernel="jax")
    cfg.validate()

    theta = dict(plan.theta)
    seed = int(theta.get("seed", 0))
    is_dare = plan.op.lower() == "dare"
    touch: Dict[str, List[int]] = {}
    coverage_rows: List[Tuple[str, int, str]] = []

    try:
        if lease.kernel == "mesh":
            realized_expert_blocks, pipe_stats = _run_mesh(
                plan, spans, writer, base_reader, expert_readers, theta,
                lease, touch, coverage_rows,
            )
        else:
            engine = _PipelineEngine(
                plan, writer, base_reader, expert_readers, theta, seed,
                is_dare, cfg, kernel_ops, lease.coalesce, touch,
                coverage_rows, resume=resume_view, spans=spans,
            )
            realized_expert_blocks, pipe_stats = engine.run()

        # -- per-shard budget soundness (lease contract) ----------------
        realized_expert_bytes = stats.c_expert - expert_read_before
        slack = 2 * lease.block_size
        slack += sum(l.reread_bytes for l in merge_layouts) - reread_before
        slack += (
            sum(r.evict_refetch_bytes for r in tiered_readers)
            - evict_refetch_before
        )
        repair_bytes = (
            sum(getattr(r, "repair_bytes", 0) for r in tiered_readers)
            + sum(getattr(l, "repair_bytes", 0) for l in merge_layouts)
            - repair_before
        )
        slack += repair_bytes
        if lease.budget >= 0 and realized_expert_bytes > lease.budget + slack:
            raise RuntimeError(
                "shard %d budget violated: realized expert bytes %d > "
                "leased %d (+%d slack)"
                % (lease.shard, realized_expert_bytes, lease.budget, slack)
            )
        # detach, not abort: region + journal stay until the coordinator
        # splices, commits, and sweeps the shard artifacts
        writer.detach()
    except BaseException as e:
        # SimulatedCrash (BaseException) falls through the Exception arm:
        # region + journal survive, open handles are released — the same
        # on-disk state a kill -9 leaves.  Real errors discard the shard.
        if isinstance(e, Exception):
            writer.abort()
        else:
            writer.detach()
        raise
    finally:
        base_reader.close()
        for r in expert_readers.values():
            r.close()
        if packed_layout is not None:
            packed_layout.close()

    doc = {
        "shard": lease.shard,
        "sid": lease.sid,
        "attempt": lease.attempt,
        "kernel": lease.kernel,
        "shard_dir": lease.shard_dir,
        "regions": writer.region_manifest(),
        "touch": {t: [int(b) for b in bs] for t, bs in touch.items()},
        "coverage": [[t, int(b), csv] for t, b, csv in coverage_rows],
        "realized_expert_bytes": realized_expert_bytes,
        "realized_expert_blocks": realized_expert_blocks,
        "resumed_blocks": resumed_blocks,
        "slack_bytes": slack - 2 * lease.block_size,
        "seconds": time.time() - t0,
        "stats": stats.snapshot(),
        "pipeline": pipe_stats,
    }
    if verify_policy is not None:
        doc["verify"] = {
            "verified_blocks": sum(v.verified_blocks for v in verifiers),
            "repaired_blocks": sum(v.repaired_blocks for v in verifiers),
            "corrupt_blocks": sum(v.corrupt_blocks for v in verifiers),
            "repair_bytes": repair_bytes,
        }
    return doc


def _run_mesh(
    plan: MergePlan,
    spans: Dict[str, Tuple[int, int]],
    writer: ShardRegionWriter,
    base_reader,
    expert_readers: Dict[str, object],
    theta: Dict,
    lease: ShardLease,
    touch: Dict[str, List[int]],
    coverage_rows: List[Tuple[str, int, str]],
) -> Tuple[int, Dict]:
    """Device-compute path: pack this shard's (whole) tensors into the
    (NB, W) block matrix and apply ``core.distributed.build_merge_step``
    once.  Requires tensor-aligned spans (the partitioner enforces this
    for ``kernel="mesh"``).  Tolerance-level on TIES tail blocks — see
    the pack_arrays docstring and tests."""
    import jax  # lazy: workers default to the numpy kernel

    from repro.core.distributed import (
        build_merge_step,
        dare_masks_packed,
        pack_arrays,
        selection_mask,
        unpack_arrays,
    )
    from jax.sharding import Mesh

    W = lease.block_size // 4
    merge_tensors: List[str] = []
    pass_through: Dict[str, List[np.ndarray]] = {}
    base_arrays: Dict[str, np.ndarray] = {}
    specs: Dict[str, object] = {}
    base_blocks: Dict[str, List[np.ndarray]] = {}
    realized = 0

    for t in plan.tensor_order:
        if t not in spans:
            continue
        spec = base_reader.spec(t)
        n_blocks = blk.num_blocks(spec.nbytes, plan.block_size)
        lo, hi = spans[t]
        if (lo, hi) != (0, n_blocks):
            raise RuntimeError(
                "mesh kernel requires tensor-aligned shard spans; got "
                "[%d, %d) of %d blocks for %r" % (lo, hi, n_blocks, t))
        specs[t] = spec
        blocks = [
            base_reader.read_block(t, b, plan.block_size, "base")
            for b in range(n_blocks)
        ]
        base_blocks[t] = blocks
        rev = plan.reverse_index(t)
        if _is_mergeable(spec) and rev:
            merge_tensors.append(t)
            base_arrays[t] = np.concatenate(
                [np.asarray(b, np.float32).reshape(-1) for b in blocks]
            ).reshape(spec.shape)
        else:
            pass_through[t] = blocks

    pipe_stats = {"kernel": "mesh", "windows": 0}
    out_arrays: Dict[str, np.ndarray] = {}
    if merge_tensors:
        arrays = {t: base_arrays[t] for t in merge_tensors}
        packed, metas = pack_arrays(arrays, W)
        n_packed = packed.shape[0]
        offsets = {name: off for name, _s, _n, off in metas}
        experts = np.zeros(
            (len(plan.expert_ids), n_packed, W), np.float32)
        for t in merge_tensors:
            D = DeltaIterator(t, plan, base_reader, expert_readers,
                              coalesce=lease.coalesce)
            rev = plan.reverse_index(t)
            for b in sorted(rev):
                x0 = base_blocks[t][b]
                deltas, eidxs, eids = D.pull(b, x0)
                realized += len(eids)
                if eids:
                    touch.setdefault(t, []).append(b)
                    coverage_rows.append((t, b, ",".join(eids)))
                for row, ei in enumerate(eidxs):
                    d = np.asarray(deltas[row], np.float32).reshape(-1)
                    experts[ei, offsets[t] + b, : d.size] = d
        select = selection_mask(plan, metas, W, n_packed)
        masks = None
        if plan.op.lower() == "dare":
            masks = dare_masks_packed(plan, metas, W, n_packed)
        devs = jax.devices()
        n_dev = max(
            d for d in range(1, len(devs) + 1) if n_packed % d == 0
        ) if n_packed else 1
        mesh = Mesh(np.array(devs[:n_dev]), ("all",))
        kind = "delta"  # DeltaIterator already materialized deltas
        step = build_merge_step(mesh, plan.op.lower(), theta, kind=kind,
                                donate=False)
        args = [packed, experts, select]
        if masks is not None:
            args.append(masks)
        out = np.asarray(step(*args))
        out_arrays = unpack_arrays(out, metas)
        pipe_stats["mesh_devices"] = n_dev
        pipe_stats["packed_blocks"] = int(n_packed)

    for t in plan.tensor_order:
        if t not in spans:
            continue
        spec = specs[t]
        n_blocks = blk.num_blocks(spec.nbytes, plan.block_size)
        writer.begin_tensor(t, spec.shape, spec.dtype)
        covered = {b: csv for tt, b, csv in coverage_rows if tt == t}
        if t in out_arrays:
            flat = np.asarray(out_arrays[t], np.float32).reshape(-1)
            elems = plan.block_size // 4
            for b in range(n_blocks):
                chunk = flat[b * elems: (b + 1) * elems]
                src = base_blocks[t][b]
                blockv = (
                    chunk.astype(np.asarray(src).dtype)
                    if b in covered else src
                )
                writer.write_block(t, b, blockv, experts=covered.get(b))
        else:
            for b in range(n_blocks):
                writer.write_block(t, b, pass_through[t][b])
        writer.finish_tensor(t)
        touch.setdefault(t, [])
    return realized, pipe_stats
