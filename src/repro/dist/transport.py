"""Worker transports — how the coordinator launches and watches shards.

The transport interface is deliberately tiny (launch a lease, poll for
an exit) and passes work by JSON document, so a real RPC backend can
drop in without touching the coordinator: a lease is what you would put
on the wire, a result doc is what would come back.

``LocalProcessTransport`` is the production-shaped default: each worker
is a separate ``python -m repro.launch.worker`` process (its own
interpreter, its own IOStats, its own readers — the honest stand-in for
a remote host).  Exit code 3 means a simulated crash (chaos); the
staged region and shard journal survive for lease re-issue.  A crashed
process takes its partial stats to the grave, exactly like real worker
death.

``InlineTransport`` runs the worker synchronously in the coordinator
process.  It exists for deterministic tests: a simulated crash is
caught and the dead attempt's partial :class:`IOStats` snapshot is
salvaged, so the `[hat, 2*hat)` crash-spend bound can be asserted over
bytes a process transport would lose.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
from typing import Dict, Optional

from repro.dist.lease import ShardLease
from repro.store.iostats import IOStats
from repro.testing.chaos import SimulatedCrash

#: process exit code signalling a SimulatedCrash (resumable death)
CRASH_EXIT = 3


@dataclasses.dataclass
class WorkerExit:
    """Terminal state of one lease attempt."""

    shard: int
    attempt: int
    ok: bool
    #: True when the worker died a *resumable* death (chaos crash or
    #: killed process) — the lease may be re-issued
    crashed: bool
    result: Optional[Dict] = None
    detail: str = ""
    #: inline transport only: the dead attempt's IOStats snapshot
    partial_stats: Optional[Dict] = None


class _ProcessHandle:
    def __init__(self, lease: ShardLease, proc: subprocess.Popen,
                 result_path: str, log_path: str):
        self.lease = lease
        self.proc = proc
        self.result_path = result_path
        self.log_path = log_path

    def poll(self) -> Optional[WorkerExit]:
        code = self.proc.poll()
        if code is None:
            return None
        if code == 0 and os.path.exists(self.result_path):
            with open(self.result_path) as f:
                return WorkerExit(self.lease.shard, self.lease.attempt,
                                  ok=True, crashed=False,
                                  result=json.load(f))
        # a 0-exit with no result doc is a commit-window death lookalike;
        # treat any non-clean outcome without a doc as a crash candidate
        crashed = code in (CRASH_EXIT, -9, -15) or (
            code == 0 and not os.path.exists(self.result_path))
        return WorkerExit(
            self.lease.shard, self.lease.attempt, ok=False, crashed=crashed,
            detail="worker exited %s (%s)" % (code, self._log_tail()),
        )

    def terminate(self) -> None:
        if self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()

    def _log_tail(self, n: int = 2000) -> str:
        try:
            with open(self.log_path, "rb") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                f.seek(max(0, size - n))
                return f.read().decode("utf-8", "replace").strip()
        except OSError:
            return "no log"


class LocalProcessTransport:
    """One subprocess per lease; lease and result travel as JSON files
    under the coordinator's shard control directory."""

    def launch(self, workspace: str, lease: ShardLease, ctl_dir: str):
        os.makedirs(ctl_dir, exist_ok=True)
        tag = "shard%d.attempt%d" % (lease.shard, lease.attempt)
        lease_path = os.path.join(ctl_dir, tag + ".lease.json")
        result_path = os.path.join(ctl_dir, tag + ".result.json")
        log_path = os.path.join(ctl_dir, tag + ".log")
        lease.write(lease_path)
        import repro

        src = os.path.dirname(os.path.dirname(os.path.abspath(
            repro.__file__)))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        log = open(log_path, "wb")
        try:
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.launch.worker",
                 "--workspace", workspace,
                 "--lease", lease_path,
                 "--result", result_path],
                stdout=log, stderr=subprocess.STDOUT, env=env,
            )
        finally:
            log.close()
        return _ProcessHandle(lease, proc, result_path, log_path)


class _InlineHandle:
    def __init__(self, exit: WorkerExit):
        self._exit = exit

    def poll(self) -> Optional[WorkerExit]:
        return self._exit

    def terminate(self) -> None:
        pass


class InlineTransport:
    """Synchronous in-process worker (tests).  Crashed attempts keep
    their IOStats snapshot so spend bounds stay assertable."""

    def launch(self, workspace: str, lease: ShardLease, ctl_dir: str):
        from repro.dist.worker import run_worker

        stats = IOStats()
        try:
            doc = run_worker(workspace, lease, stats=stats)
            ex = WorkerExit(lease.shard, lease.attempt, ok=True,
                            crashed=False, result=doc)
        except SimulatedCrash as e:
            ex = WorkerExit(
                lease.shard, lease.attempt, ok=False, crashed=True,
                detail=str(e), partial_stats=stats.snapshot(),
            )
        return _InlineHandle(ex)


def make_transport(name: str):
    if name == "process":
        return LocalProcessTransport()
    if name == "inline":
        return InlineTransport()
    raise ValueError("unknown transport %r" % (name,))
