"""Shard-parallel distributed merge execution (docs/DISTRIBUTED.md).

Coordinator/worker subsystem that scatters one planned merge across
byte-balanced shard workers and rolls the staged regions back into a
single transactional commit:

* :mod:`repro.dist.partition` — physical-byte shard partitioning over
  the plan's realized read set;
* :mod:`repro.dist.lease` — :class:`ShardLease` work orders and
  :class:`DistOptions` knobs;
* :mod:`repro.dist.region` — shard-side staged output regions (local
  StagingWriter + per-shard progress journal);
* :mod:`repro.dist.worker` — one lease in, one region + result doc out;
* :mod:`repro.dist.transport` — process / inline worker transports;
* :mod:`repro.dist.coordinator` — scatter, lease re-issue, splice,
  single atomic publish.

Deliberately jax-free at import time: only a worker running
``kernel="mesh"`` touches :mod:`repro.core.distributed`.
"""
from repro.dist.coordinator import run_sharded_merge, shard_journal_root
from repro.dist.lease import DistOptions, ShardLease
from repro.dist.partition import Partition, Shard, partition_plan
from repro.dist.region import ShardRegionWriter
from repro.dist.transport import (
    InlineTransport,
    LocalProcessTransport,
    WorkerExit,
    make_transport,
)
from repro.dist.worker import run_worker

__all__ = [
    "DistOptions",
    "InlineTransport",
    "LocalProcessTransport",
    "Partition",
    "Shard",
    "ShardLease",
    "ShardRegionWriter",
    "WorkerExit",
    "make_transport",
    "partition_plan",
    "run_sharded_merge",
    "run_worker",
    "shard_journal_root",
]
