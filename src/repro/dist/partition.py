"""Byte-balanced shard partitioning over a plan's realized read set.

The unit of partitioning is the OUTPUT block: every expert block that
contributes to output block ``(tensor, b)`` must be read by whichever
worker owns that block, so shards are contiguous prefixes of the global
output-block order (``plan.tensor_order`` x block index).  Contiguity
keeps each shard a set of per-tensor half-open spans — the shape the
pipelined engine's ``spans`` parameter and the region splice both want —
and preserves the strict in-order streaming discipline of
:class:`~repro.store.snapshot.StagingWriter` within a shard.

Costing mirrors the planner's marginal-byte accounting
(``planner._selection_bytes``): flat blocks bill their physical (ragged
tail) size, elided packed blocks bill zero, and a packed extent bills
once per shard that touches it.  An extent whose covered blocks straddle
a cut is physically re-read by every later shard that needs it; those
duplicate bytes are reported per shard (they widen that shard's budget)
and in total (they widen the coordinator's budget slack).

Cuts are chosen by greedy prefix sums over pure expert cost — the term
the paper budgets — giving the classic bound ``E_i <= E/n + max_unit``
where ``max_unit`` is one output block's expert bytes.  A second pass
respaces any cuts that landed inside a maximal run of zero-expert-cost
blocks evenly by block count: moving a cut within such a run cannot
change any shard's expert bytes, but it balances the base-read/output-
write work that pure expert costing is blind to (and yields an even
split when the plan selects nothing at all).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import blocks as blk
from repro.core.catalog import Catalog
from repro.core.plan import MergePlan
from repro.core.planner import _selection_bytes


@dataclasses.dataclass
class Shard:
    """One worker's slice of the output-block space."""

    shard: int
    #: tensor -> (lo, hi) half-open GLOBAL block spans, plan tensor order
    spans: Dict[str, Tuple[int, int]]
    #: physical expert bytes this shard reads (each extent charged once)
    expert_bytes: int
    #: expert_bytes including cross-shard extent re-reads — the lease's
    #: per-shard byte budget before executor-style honesty widenings
    budget: int
    n_blocks: int

    @property
    def empty(self) -> bool:
        return self.n_blocks == 0


@dataclasses.dataclass
class Partition:
    shards: List[Shard]
    #: extent-once global total — equals the planner's marginal
    #: accounting of the realized read set (C^_expert physical)
    total_expert_bytes: int
    #: extra bytes moved because shared extents straddle cuts
    duplicate_extent_bytes: int
    #: (tensor, n_blocks) in plan.tensor_order — the global block order
    order: List[Tuple[str, int]]

    @property
    def n_shards(self) -> int:
        return len(self.shards)


def _tensor_blocks(plan: MergePlan, catalog: Catalog) -> List[Tuple[str, int]]:
    sizes = {r[0]: int(r[3]) for r in catalog.tensor_metas(plan.base_id)}
    order = []
    for t in plan.tensor_order:
        if t not in sizes:
            raise KeyError(
                "tensor %r in plan order but not analyzed for base %r"
                % (t, plan.base_id))
        order.append((t, blk.num_blocks(sizes[t], plan.block_size)))
    return order


def _spans_from_range(
    order: List[Tuple[str, int]], offsets: Dict[str, int], lo: int, hi: int
) -> Dict[str, Tuple[int, int]]:
    spans: Dict[str, Tuple[int, int]] = {}
    for t, n in order:
        off = offsets[t]
        s_lo, s_hi = max(lo, off), min(hi, off + n)
        if s_hi > s_lo:
            spans[t] = (s_lo - off, s_hi - off)
    return spans


def partition_plan(
    plan: MergePlan,
    catalog: Catalog,
    n_shards: int,
    align: str = "block",
) -> Partition:
    """Cut the global output-block order into ``n_shards`` contiguous
    ranges balanced on physical expert bytes.

    ``align="tensor"`` snaps every cut to a tensor boundary (required by
    the mesh kernel, which packs whole tensors); the expert-byte bound
    then loosens from one block to one tensor of slack.
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if align not in ("block", "tensor"):
        raise ValueError("align must be 'block' or 'tensor'")
    order = _tensor_blocks(plan, catalog)
    offsets: Dict[str, int] = {}
    total = 0
    for t, n in order:
        offsets[t] = total
        total += n

    # per-global-block expert cost; extents attributed to their first
    # covering block for the prefix sums, then re-costed per shard below
    cost = [0] * total
    extent_size: Dict[str, int] = {}
    extent_blocks: Dict[str, set] = {}
    for (e, t, b), (nbytes, extent_key) in _selection_bytes(
            catalog, plan, {}).items():
        if t not in offsets:
            continue
        g = offsets[t] + b
        if extent_key is None:
            cost[g] += nbytes
        else:
            extent_size[extent_key] = max(
                extent_size.get(extent_key, 0), nbytes)
            extent_blocks.setdefault(extent_key, set()).add(g)
    for key, gs in extent_blocks.items():
        cost[min(gs)] += extent_size[key]

    cuts = _prefix_cuts(cost, total, n_shards)
    if align == "tensor":
        cuts = _snap_to_tensor_boundaries(cuts, order, offsets, total)

    bounds = [0] + cuts + [total]
    shards: List[Shard] = []
    duplicate = 0
    extent_once_total = sum(cost)
    for k in range(n_shards):
        lo, hi = bounds[k], bounds[k + 1]
        flat = sum(
            c for g, c in enumerate(cost) if lo <= g < hi
        )
        # cost[] already charges each extent once globally (at its first
        # block); a shard whose span contains only LATER blocks of an
        # extent still physically reads it — add that re-read here
        reread = 0
        for key, gs in extent_blocks.items():
            first = min(gs)
            if not (lo <= first < hi) and any(lo <= g < hi for g in gs):
                reread += extent_size[key]
        duplicate += reread
        shards.append(Shard(
            shard=k,
            spans=_spans_from_range(order, offsets, lo, hi),
            expert_bytes=flat + reread,
            budget=flat + reread,
            n_blocks=hi - lo,
        ))
    return Partition(
        shards=shards,
        total_expert_bytes=extent_once_total,
        duplicate_extent_bytes=duplicate,
        order=order,
    )


def _prefix_cuts(cost: List[int], total: int, n_shards: int) -> List[int]:
    """n_shards-1 cut indices: greedy prefix targets over expert cost,
    then zero-run respacing for block-count balance where expert cost
    cannot discriminate."""
    E = sum(cost)
    cuts: List[int] = []
    if E > 0:
        cum = 0
        targets = [E * (k + 1) / n_shards for k in range(n_shards - 1)]
        ti = 0
        for g in range(total):
            cum += cost[g]
            while ti < len(targets) and cum >= targets[ti]:
                cuts.append(g + 1)
                ti += 1
        while len(cuts) < n_shards - 1:
            cuts.append(total)
    else:
        cuts = [0] * (n_shards - 1)

    # respace cuts stuck inside (or at the edge of) a zero-cost run —
    # moving them within the run is free in expert bytes
    out: List[int] = []
    i = 0
    while i < len(cuts):
        c = cuts[i]
        run_lo, run_hi = _zero_run(cost, total, c)
        j = i
        while j < len(cuts) and run_lo <= cuts[j] <= run_hi:
            j += 1
        n_in_run = j - i
        if n_in_run > 0 and run_hi > run_lo:
            prev = out[-1] if out else 0
            span_lo = max(run_lo, prev)
            width = run_hi - span_lo
            for m in range(n_in_run):
                out.append(span_lo + (width * (m + 1)) // (n_in_run + 1)
                           if width > 0 else span_lo)
            i = j
        else:
            out.append(c)
            i += 1
    # monotonic, clamped
    fixed: List[int] = []
    prev = 0
    for c in out:
        c = max(prev, min(c, total))
        fixed.append(c)
        prev = c
    return fixed


def _zero_run(cost: List[int], total: int, c: int) -> Tuple[int, int]:
    """Maximal [lo, hi] index range such that every cut position in it
    splits only zero-cost blocks around position ``c``."""
    lo = c
    while lo > 0 and cost[lo - 1] == 0:
        lo -= 1
    hi = c
    while hi < total and cost[hi] == 0:
        hi += 1
    return lo, hi


def _snap_to_tensor_boundaries(
    cuts: List[int], order: List[Tuple[str, int]],
    offsets: Dict[str, int], total: int,
) -> List[int]:
    boundaries = sorted({offsets[t] for t, _n in order} | {total})
    snapped: List[int] = []
    prev = 0
    for c in cuts:
        best = min(boundaries, key=lambda b: (abs(b - c), b))
        best = max(best, prev)
        snapped.append(best)
        prev = best
    return snapped
