"""Coordinator — scatter one plan across shard workers, commit once.

``run_sharded_merge`` is the distributed twin of
:func:`repro.core.executor.execute_merge`: same inputs, same manifest,
same transactional guarantees, same return shape.  It partitions the
plan's realized read set into byte-balanced shards
(:mod:`repro.dist.partition`), issues a :class:`ShardLease` per shard
over a transport (:mod:`repro.dist.transport`), and watches for exits:

* a clean exit yields a result doc — staged region manifest, global
  touch/coverage, per-shard IOStats snapshot;
* a resumable death (chaos crash, killed process) expires the lease and
  the shard is re-issued at ``attempt + 1`` — the successor resumes
  from the shard journal's high-water mark, so crash + resume reads
  each residual byte once and total expert spend stays inside the
  ``[hat, 2*hat)`` requeue bound;
* anything else aborts the whole window (all-shards-or-nothing).

Once every shard lands, the coordinator splices the regions — in plan
tensor order, verifying each region's streaming hash as it reads — into
ONE real :class:`StagingWriter` under the job's
:class:`TransactionManager`, then publishes exactly the way
``execute_merge`` does: one atomic rename, one commit record, one
coverage/touch/DAG write-back.  Worker stats roll up into the job's
:class:`IOStats` under a per-shard dimension; canonical ``out`` bytes
are billed once (at splice), region and journal overhead land in
``other``/``journal`` — see docs/DISTRIBUTED.md for the parity story.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import shutil
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import blocks as blk
from repro.core.catalog import Catalog
from repro.core.executor import (
    MergeResult,
    PipelineConfig,
    _check_cancel,
    _ranges_from_indices,
)
from repro.core.plan import MergePlan
from repro.core.transactions import TransactionManager
from repro.dist.lease import DistOptions, ShardLease
from repro.dist.partition import Partition, partition_plan
from repro.dist.transport import make_transport
from repro.store.journal import journal_path
from repro.store.snapshot import SnapshotStore


def shard_journal_root(snapshots: SnapshotStore) -> str:
    """Shard journals live one directory below the service journal root
    so ``TransactionManager.recover()`` (which lists only top-level
    ``*.journal`` files) never mistakes a shard journal for a dead
    service-level run — shard recovery is the coordinator's job."""
    return os.path.join(snapshots.journal_root, "shards")


def _shard_journal_path(snapshots: SnapshotStore, sid: str, shard: int) -> str:
    return journal_path(
        shard_journal_root(snapshots), "%s.shard%d" % (sid, shard))


def run_sharded_merge(
    plan: MergePlan,
    snapshots: SnapshotStore,
    catalog: Catalog,
    sid: Optional[str] = None,
    txn: Optional[TransactionManager] = None,
    options: Optional[DistOptions] = None,
    coalesce: bool = True,
    verify=True,
    pipeline: Optional[PipelineConfig] = None,
    cancel=None,
    progress=None,
    resume=None,
) -> MergeResult:
    t0 = time.time()
    options = options or DistOptions()
    options.validate()
    stats = snapshots.stats
    expert_read_before = stats.c_expert
    txn = txn or TransactionManager(snapshots, catalog)
    sid = sid or TransactionManager.new_sid()
    workspace = os.path.dirname(snapshots.staging_root)

    if resume is not None:
        if resume.sid != sid:
            raise ValueError(
                "resume state is for sid %r, not %r" % (resume.sid, sid))
        if resume.plan_digest != plan.digest():
            resume.discard()
            resume = None

    align = "tensor" if options.kernel == "mesh" else "block"
    part = partition_plan(plan, catalog, options.n_workers, align=align)
    live = [s for s in part.shards if not s.empty]
    shard_root = os.path.join(snapshots.staging_root, "shards", sid)
    ctl_dir = os.path.join(shard_root, "ctl")
    os.makedirs(shard_root, exist_ok=True)
    os.makedirs(shard_journal_root(snapshots), exist_ok=True)
    transport = make_transport(options.transport)

    verify_doc = (
        dataclasses.asdict(verify) if dataclasses.is_dataclass(verify)
        else bool(verify)
    )
    pipeline_doc = (
        dataclasses.asdict(pipeline) if pipeline is not None else None
    )

    def _lease(shard, attempt: int, with_chaos: bool) -> ShardLease:
        chaos = None
        if (with_chaos and options.chaos
                and int(options.chaos.get("shard", 0)) == shard.shard):
            chaos = {k: v for k, v in options.chaos.items() if k != "shard"}
        return ShardLease(
            shard=shard.shard,
            sid=sid,
            attempt=attempt,
            budget=shard.budget,
            spans=[(t, lo, hi) for t, (lo, hi) in shard.spans.items()],
            plan=plan.to_payload(),
            block_size=plan.block_size,
            shard_dir=os.path.join(shard_root, "shard%d" % shard.shard),
            journal_path=_shard_journal_path(snapshots, sid, shard.shard),
            coalesce=coalesce,
            verify=verify_doc,
            kernel=options.kernel,
            pipeline=pipeline_doc,
            journal_sync_every=options.journal_sync_every,
            chaos=chaos,
        )

    by_shard = {s.shard: s for s in live}
    pending: Dict[int, object] = {}
    attempts: Dict[int, int] = {}
    docs: Dict[int, Dict] = {}
    crashed_stats: List[Tuple[int, Dict]] = []
    reissued = 0
    total_blocks = sum(s.n_blocks for s in live)
    done_blocks = 0

    try:
        _check_cancel(cancel, sid)
        for s in live:
            attempts[s.shard] = 1
            pending[s.shard] = transport.launch(
                workspace, _lease(s, 1, with_chaos=True), ctl_dir)

        # -- watch the fleet; expire + re-issue dead leases -------------
        while pending:
            _check_cancel(cancel, sid)
            moved = False
            for k in sorted(pending):
                ex = pending[k].poll()
                if ex is None:
                    continue
                moved = True
                del pending[k]
                if ex.ok:
                    docs[k] = ex.result
                    done_blocks += by_shard[k].n_blocks
                    if progress is not None:
                        progress(done_blocks, total_blocks)
                    continue
                if ex.partial_stats is not None:
                    crashed_stats.append((k, ex.partial_stats))
                if not ex.crashed or attempts[k] >= options.max_lease_attempts:
                    raise RuntimeError(
                        "shard %d failed%s: %s"
                        % (k, "" if ex.crashed is False else
                           " after %d attempts" % attempts[k], ex.detail))
                # lease expired: re-issue to a survivor slot; the chaos
                # armed on attempt 1 is NOT re-armed, so the successor
                # resumes from the shard journal and completes
                attempts[k] += 1
                reissued += 1
                pending[k] = transport.launch(
                    workspace, _lease(by_shard[k], attempts[k],
                                      with_chaos=False), ctl_dir)
            if pending and not moved:
                time.sleep(options.heartbeat_s)

        # -- roll up worker stats under the shard dimension -------------
        for k, doc in sorted(docs.items()):
            stats.absorb(doc["stats"], shard=str(k))
        for k, snap in crashed_stats:
            stats.absorb(snap, shard=str(k))

        # -- budget soundness across the fleet --------------------------
        realized_expert_bytes = stats.c_expert - expert_read_before
        if plan.budget_b >= 0:
            slack = 2 * plan.block_size * max(1, len(live))
            # extents straddling shard cuts move once per shard (priced
            # by the partitioner, not the planner)
            slack += part.duplicate_extent_bytes
            # per-worker honesty widenings (cap rereads, evict refetch,
            # read repair) — already itemized in each result doc
            slack += sum(doc.get("slack_bytes", 0) for doc in docs.values())
            # each expired lease may have spent up to its shard budget
            # before dying: the [hat, 2*hat) requeue allowance
            slack += sum(
                (attempts[k] - 1) * (by_shard[k].budget + 2 * plan.block_size)
                for k in attempts
            )
            if realized_expert_bytes > plan.c_expert_hat + slack:
                raise RuntimeError(
                    "budget soundness violated: realized expert bytes "
                    "%d > planned %d (+%d distributed slack)"
                    % (realized_expert_bytes, plan.c_expert_hat, slack))

        # -- splice regions into the real staged snapshot ----------------
        touch, coverage_rows, realized_expert_blocks = _merge_docs(docs)
        if resume is not None:
            writer = txn.begin(resume=resume)
        else:
            writer = txn.begin(sid=sid, plan=plan)
        base_reader = snapshots.models.open_model(plan.base_id)
        try:
            _splice(plan, writer, base_reader, docs, stats,
                    coverage_rows, resume)
        finally:
            base_reader.close()
        writer.validate_hashes()

        theta = {k: v for k, v in plan.theta.items()
                 if not str(k).startswith("_")}
        manifest = {
            "sid": sid,
            "plan_id": plan.plan_id,
            "base_id": plan.base_id,
            "expert_ids": plan.expert_ids,
            "op": plan.op,
            "theta": theta,
            "budget_b": plan.budget_b,
            "c_expert_hat": plan.c_expert_hat,
            "c_expert_logical_hat": plan.logical_hat,
            "c_expert_run": realized_expert_bytes,
            "plan_digest": plan.digest(),
            "block_size": plan.block_size,
            "layout_id": plan.layout_id,
            "execution": "sharded",
            "n_workers": options.n_workers,
        }
        sid = txn.atomic_publish(writer, manifest)
        manifest["output_root"] = snapshots.manifest(sid)["output_root"]
        txn.commit_record(sid, manifest)
        catalog.record_touch_map(
            sid, {t: _ranges_from_indices(ix) for t, ix in touch.items()}
        )
        catalog.record_coverage(sid, coverage_rows)
        if plan.parent_sids:
            catalog.record_dag_edges(
                sid,
                [
                    (p, "base" if p == plan.base_id else "expert")
                    for p in plan.parent_sids
                ],
            )
        if writer.journal is not None:
            writer.journal.remove()
        txn.commit()
        # all-shards-or-nothing landed: sweep every shard artifact so a
        # committed window leaves zero staging residue
        _cleanup_shards(snapshots, shard_root, sid, live)
    except Exception:
        for h in pending.values():
            h.terminate()
        _cleanup_shards(snapshots, shard_root, sid, live)
        txn.abort()
        raise

    run_stats = {
        "seconds": time.time() - t0,
        "c_expert_run": realized_expert_bytes,
        "c_expert_hat": plan.c_expert_hat,
        "realized_expert_blocks": realized_expert_blocks,
        "compute": "sharded",
        "coalesce": coalesce,
        "resumed_blocks": sum(
            doc.get("resumed_blocks", 0) for doc in docs.values()),
        "execution": "sharded",
        "n_workers": options.n_workers,
        "transport": options.transport,
        "kernel": options.kernel,
        "reissued": reissued,
        "partition": {
            "total_expert_bytes": part.total_expert_bytes,
            "duplicate_extent_bytes": part.duplicate_extent_bytes,
            "shards": [
                {
                    "shard": s.shard,
                    "n_blocks": s.n_blocks,
                    "expert_bytes": s.expert_bytes,
                    "budget": s.budget,
                }
                for s in part.shards
            ],
        },
        "shards": [
            {
                "shard": k,
                "attempts": attempts[k],
                "realized_expert_bytes": doc["realized_expert_bytes"],
                "realized_expert_blocks": doc["realized_expert_blocks"],
                "resumed_blocks": doc.get("resumed_blocks", 0),
                "seconds": doc["seconds"],
            }
            for k, doc in sorted(docs.items())
        ],
    }
    verify_docs = [doc["verify"] for doc in docs.values() if "verify" in doc]
    if verify_docs:
        run_stats["verify"] = {
            key: sum(v[key] for v in verify_docs)
            for key in ("verified_blocks", "repaired_blocks",
                        "corrupt_blocks", "repair_bytes")
        }
    return MergeResult(sid, manifest, run_stats)


def _merge_docs(docs: Dict[int, Dict]):
    """Merge worker touch/coverage (already GLOBAL-indexed) in shard
    order — spans are disjoint, so concatenation is exact."""
    touch: Dict[str, List[int]] = {}
    coverage_rows: List[Tuple[str, int, str]] = []
    realized_blocks = 0
    for k in sorted(docs):
        doc = docs[k]
        realized_blocks += doc["realized_expert_blocks"]
        for t, bs in doc["touch"].items():
            touch.setdefault(t, []).extend(int(b) for b in bs)
        for t, b, csv in doc["coverage"]:
            coverage_rows.append((t, int(b), csv))
    for t in touch:
        touch[t] = sorted(touch[t])
    coverage_rows.sort(key=lambda r: (r[0], r[1]))
    return touch, coverage_rows, realized_blocks


def _splice(plan, writer, base_reader, docs, stats, coverage_rows, resume):
    """Stream every region file through the real StagingWriter in plan
    order, verifying each region's blake2b-16 against the worker's
    streaming hash.  Output bytes are billed here, once, to ``out``
    (inside write_block); region reads land in ``other``."""
    regions_by_tensor: Dict[str, List[Tuple[Dict, str]]] = {}
    for k in sorted(docs):
        doc = docs[k]
        shard_dir = _shard_dir_of(doc)
        for region in doc["regions"]:
            regions_by_tensor.setdefault(region["tensor"], []).append(
                (region, shard_dir))
    csv_by_block = {(t, b): csv for t, b, csv in coverage_rows}
    for tensor_id in plan.tensor_order:
        spec = base_reader.spec(tensor_id)
        n_blocks = blk.num_blocks(spec.nbytes, plan.block_size)
        regions = sorted(
            regions_by_tensor.get(tensor_id, []),
            key=lambda rs: rs[0]["lo"])
        covered = sum(r["hi"] - r["lo"] for r, _d in regions)
        if covered != n_blocks or (regions and regions[0][0]["lo"] != 0):
            raise IOError(
                "shard regions do not tile tensor %r: %d of %d blocks"
                % (tensor_id, covered, n_blocks))
        skip = 0
        if resume is not None:
            tr = resume.tensors.get(tensor_id)
            if tr is not None:
                skip = tr.n_validated
        writer.begin_tensor(tensor_id, spec.shape, spec.dtype)
        for region, shard_dir in regions:
            path = os.path.join(shard_dir, region["file"])
            h = hashlib.blake2b(digest_size=16)
            with open(path, "rb") as f:
                for b in range(region["lo"], region["hi"]):
                    nb = blk.block_range(
                        spec.nbytes, b, plan.block_size).nbytes
                    raw = f.read(nb)
                    if len(raw) != nb:
                        raise IOError(
                            "short region read for %r block %d"
                            % (tensor_id, b))
                    h.update(raw)
                    stats.record_read("other", nb)
                    if b < skip:
                        continue  # coordinator resume: already staged
                    writer.write_block(
                        tensor_id, b, np.frombuffer(raw, np.uint8),
                        experts=csv_by_block.get((tensor_id, b)),
                    )
            if h.hexdigest() != region["hash"]:
                raise IOError(
                    "region hash mismatch for %r [%d, %d) from shard "
                    "staging %r" % (tensor_id, region["lo"], region["hi"],
                                    shard_dir))
        writer.finish_tensor(tensor_id)


def _shard_dir_of(doc: Dict) -> str:
    # the lease pinned the shard dir; workers echo regions relative to it
    return doc["shard_dir"]


def _cleanup_shards(snapshots, shard_root, sid, live) -> None:
    shutil.rmtree(shard_root, ignore_errors=True)
    for s in live:
        try:
            os.unlink(_shard_journal_path(snapshots, sid, s.shard))
        except OSError:
            pass
