"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU-idiomatic dropping implementation (MaxText-style):
  1. router top-k over experts, renormalized weights;
  2. token-expert pairs sorted by expert id; each expert receives a
     *static-capacity* slice C = ceil(T·k/E · capacity_factor) (rounded to
     a 128 multiple so the token dim shards cleanly over data axes) —
     overflow tokens are dropped (standard GShard semantics);
  3. per-expert batched GEMMs via einsum('ecd,edf->ecf') — dense, static
     shapes, MXU-aligned;
  4. results gathered back to token order and combined with router weights.

Expert weights are laid out (L, E, D, F): D FSDP-sharded over "data", F
tensor-parallel over "model"; E stays unsharded so arbitrary expert
counts (grok's 8, deepseek's 64) divide nothing.  Shared experts
(DeepSeek) run as one fused dense SwiGLU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.shardctx import constrain, get_mesh


def _batch_axes(b: int, mesh) -> tuple:
    """Mesh axes carrying the batch dim (divisibility-checked), else ()."""
    if mesh is None:
        return ()
    from repro.models.shardctx import resolve

    spec = resolve(("batch",), (b,))
    axes = spec[0]
    if axes is None:
        return ()
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    raw = int(
        n_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts
    )
    return max(128, ((raw + 127) // 128) * 128)


def init_moe(key, cfg: ModelConfig, n_layers: int, dtype) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    e = cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (n_layers, d, e), in_axis=1, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (n_layers, e, d, f), in_axis=2, dtype=dtype),
        "w_up": dense_init(ks[2], (n_layers, e, d, f), in_axis=2, dtype=dtype),
        "w_down": dense_init(ks[3], (n_layers, e, f, d), in_axis=2, dtype=dtype),
    }
    s = {
        "router": ("stack", "fsdp", None),
        "w_gate": ("stack", None, "fsdp", "mlp"),
        "w_up": ("stack", None, "fsdp", "mlp"),
        "w_down": ("stack", None, "mlp", "fsdp"),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["ws_gate"] = dense_init(ks[4], (n_layers, d, fs), dtype=dtype)
        p["ws_up"] = dense_init(ks[5], (n_layers, d, fs), dtype=dtype)
        p["ws_down"] = dense_init(ks[4], (n_layers, fs, d), dtype=dtype)
        s["ws_gate"] = ("stack", "fsdp", "mlp")
        s["ws_up"] = ("stack", "fsdp", "mlp")
        s["ws_down"] = ("stack", "mlp", "fsdp")
    return p, s


def moe_ffn(pl: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x (B, S, D) -> (B, S, D) — shard-local per-row dispatch.

    §Perf hillclimb H1: the original global dispatch (kept below as
    :func:`moe_ffn_global`) sorts/gathers over ALL B·S tokens, which GSPMD
    can only shard by inserting full-tensor gathers — ~340 GB of
    all-reduce per grok train step.  Routing each sequence row
    independently (vmap over B) keeps every sort/scatter local to the
    row's data shard: cross-device traffic drops to the unavoidable FSDP
    weight all-gathers + TP partial sums.  Per-row capacity
    C = max(k, ceil(S·k·cf / E)) keeps expected drop rates identical.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = max(k, _row_capacity(s, cfg))
    router = pl["router"]

    def row_dispatch(x_row: jnp.ndarray):
        """(S, D) -> dispatch buffer (E, C, D) + routing state."""
        logits = jnp.einsum("td,de->te", x_row.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)                  # (S, k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        flat_e = idx.reshape(-1)                          # (S*k,)
        sort_idx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sort_idx]
        tok_of_pair = sort_idx // k
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        seg_pos = jnp.arange(s * k) - starts[sorted_e]
        keep = seg_pos < cap
        slot = jnp.where(keep, seg_pos, cap - 1)
        gathered = jnp.where(keep[:, None], x_row[tok_of_pair], 0.0)
        buf = jnp.zeros((e, cap, d), x.dtype)
        buf = buf.at[sorted_e, slot].add(gathered.astype(x.dtype))
        return buf, (sorted_e, slot, keep, sort_idx, w)

    def row_combine(y_exp, route):
        sorted_e, slot, keep, sort_idx, w = route
        y_pair_sorted = jnp.where(keep[:, None], y_exp[sorted_e, slot], 0.0)
        inv = jnp.zeros_like(sort_idx).at[sort_idx].set(jnp.arange(s * k))
        y_pair = y_pair_sorted[inv].reshape(s, k, d)
        return jnp.einsum("tkd,tk->td", y_pair.astype(jnp.float32),
                          w).astype(x.dtype)

    dispatch = jax.vmap(row_dispatch)
    combine = jax.vmap(row_combine)

    # H1 iteration 3: force shard-local routing with shard_map.  Under
    # plain GSPMD the scatter/gather chains lose the batch sharding (the
    # partitioner replicates B and pays ~107 GB/layer of all-reduce on
    # grok); shard_map pins dispatch/combine to the batch shards so the
    # only cross-device traffic left is the expert-GEMM partial sums.
    mesh = get_mesh()
    batch_axes = _batch_axes(b, mesh)
    if batch_axes:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        bt = P(batch_axes)
        route_specs = (bt, bt, bt, bt, bt)
        dispatch = shard_map(
            dispatch, mesh=mesh, in_specs=(bt,),
            out_specs=(bt, route_specs), check_rep=False,
        )
        combine = shard_map(
            combine, mesh=mesh, in_specs=(bt, route_specs),
            out_specs=bt, check_rep=False,
        )

    buf, route = dispatch(x)                       # (B, E, C, D) B-sharded
    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buf, pl["w_gate"].astype(x.dtype))
    ) * jnp.einsum("becd,edf->becf", buf, pl["w_up"].astype(x.dtype))
    h = constrain(h, ("batch", None, None, "mlp"))
    y_exp = jnp.einsum("becf,efd->becd", h, pl["w_down"].astype(x.dtype))
    y = combine(y_exp, route)
    y = constrain(y, ("batch", None, None))

    if cfg.n_shared_experts:
        x2 = x.reshape(b * s, d)
        hs = jax.nn.silu(
            jnp.einsum("td,df->tf", x2, pl["ws_gate"].astype(x.dtype))
        ) * jnp.einsum("td,df->tf", x2, pl["ws_up"].astype(x.dtype))
        y = y + jnp.einsum("tf,fd->td", hs,
                           pl["ws_down"].astype(x.dtype)).reshape(b, s, d)
    return y


def _row_capacity(seq: int, cfg: ModelConfig) -> int:
    raw = int(seq * cfg.experts_per_token * cfg.capacity_factor
              / cfg.n_experts)
    return max(8, ((raw + 7) // 8) * 8)


def moe_ffn_global(pl: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Original global-token dispatch (ablation baseline for §Perf H1)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(t, cfg)
    x2 = x.reshape(t, d)

    # --- routing (float32 for numerics) ---------------------------------
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), pl["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                      # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch --------------------------------------------
    flat_e = idx.reshape(-1)                               # (T*k,)
    sort_idx = jnp.argsort(flat_e, stable=True)            # pair permutation
    sorted_e = flat_e[sort_idx]
    tok_of_pair = sort_idx // k
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts                   # segment starts
    seg_pos = jnp.arange(t * k) - starts[sorted_e]
    keep = seg_pos < cap
    slot = jnp.where(keep, seg_pos, cap - 1)

    gathered = jnp.where(keep[:, None], x2[tok_of_pair], 0.0)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, slot].add(gathered.astype(x.dtype))
    buf = constrain(buf, (None, "batch", None))

    # --- per-expert SwiGLU (batched GEMMs, MXU-aligned) ------------------
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, pl["w_gate"].astype(x.dtype))
    ) * jnp.einsum("ecd,edf->ecf", buf, pl["w_up"].astype(x.dtype))
    h = constrain(h, (None, "batch", "mlp"))
    y_exp = jnp.einsum("ecf,efd->ecd", h, pl["w_down"].astype(x.dtype))

    # --- combine back to token order -------------------------------------
    y_pair_sorted = jnp.where(
        keep[:, None], y_exp[sorted_e, slot], 0.0
    )  # (T*k, D)
    inv = jnp.zeros_like(sort_idx).at[sort_idx].set(jnp.arange(t * k))
    y_pair = y_pair_sorted[inv].reshape(t, k, d)
    y = jnp.einsum("tkd,tk->td", y_pair.astype(jnp.float32), w).astype(x.dtype)

    # --- shared experts (dense) ------------------------------------------
    if cfg.n_shared_experts:
        hs = jax.nn.silu(
            jnp.einsum("td,df->tf", x2, pl["ws_gate"].astype(x.dtype))
        ) * jnp.einsum("td,df->tf", x2, pl["ws_up"].astype(x.dtype))
        y = y + jnp.einsum("tf,fd->td", hs, pl["ws_down"].astype(x.dtype))

    return y.reshape(b, s, d)
