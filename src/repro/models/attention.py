"""Attention: chunked (flash-style) GQA, local windows, cross-attn, MLA,
and single-token decode against KV caches.

Memory-safe by construction: prefill/train attention never materializes
the (S, S) score matrix.  Queries are processed in chunks (lax.map) with
an online-softmax scan over key chunks — the pure-JAX equivalent of a
flash kernel; XLA fuses each (cq × ck) tile in VMEM.  Peak activation is
O(S·cq + cq·ck) per head group instead of O(S²).

GQA never materializes repeated KV: queries are reshaped to
(B, S, n_kv, q_per_kv, hd) and contracted against un-repeated KV heads.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    apply_rope,
    dense_init,
    head_rmsnorm,
    rmsnorm,
)
from repro.models.shardctx import constrain

_NEG = -1.0e30


def pl_cdiv(a, b):
    return (a + b - 1) // b


# ------------------------------------------------------------------ params
def init_attention(key, cfg: ModelConfig, n_layers: int, dtype) -> Tuple[Dict, Dict]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (n_layers, d, nq, hd), in_axis=1, dtype=dtype),
        "wk": dense_init(ks[1], (n_layers, d, nkv, hd), in_axis=1, dtype=dtype),
        "wv": dense_init(ks[2], (n_layers, d, nkv, hd), in_axis=1, dtype=dtype),
        "wo": dense_init(ks[3], (n_layers, nq, hd, d), in_axis=2, dtype=dtype),
    }
    s = {
        "wq": ("stack", "fsdp", "heads", None),
        "wk": ("stack", "fsdp", "kv_heads", None),
        "wv": ("stack", "fsdp", "kv_heads", None),
        "wo": ("stack", "heads", None, "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, nq, hd), dtype)
        p["bk"] = jnp.zeros((n_layers, nkv, hd), dtype)
        p["bv"] = jnp.zeros((n_layers, nkv, hd), dtype)
        s["bq"] = ("stack", "heads", None)
        s["bk"] = ("stack", "kv_heads", None)
        s["bv"] = ("stack", "kv_heads", None)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((n_layers, hd), dtype)
        p["k_norm"] = jnp.zeros((n_layers, hd), dtype)
        s["q_norm"] = ("stack", None)
        s["k_norm"] = ("stack", None)
    return p, s


def qkv_project(
    pl: Dict, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> q (B, S, H, hd), k/v (B, S, Hkv, hd), roped+normed."""
    q = jnp.einsum("bsd,dhk->bshk", x, pl["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, pl["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, pl["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + pl["bq"].astype(x.dtype)
        k = k + pl["bk"].astype(x.dtype)
        v = v + pl["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = head_rmsnorm(q, pl["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, pl["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


# ------------------------------------------------- chunked flash attention
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,  # (B, Sk, Hkv, hd)
    causal: bool = True,
    window: int = 0,       # 0 = unlimited; >0 = local causal window
    q_offset: int = 0,     # absolute position of q[0] (cache append)
    cq: int = 512,
    ck: int = 1024,
    skip_masked_chunks: bool = False,
) -> jnp.ndarray:
    """Chunked online-softmax attention.

    ``skip_masked_chunks`` (§Perf H3): bound the key loop per q-chunk to
    the causally (and window-) reachable k-chunks via a dynamic
    ``fori_loop`` — halves causal-attention FLOPs (and cuts local-window
    FLOPs to the window fraction).  Inference-only: dynamic-trip-count
    loops are not reverse-differentiable, so training paths keep the
    static scan (full tiles + masking).
    """
    b, sq, h, hd = q.shape
    _, sk, hkv, _ = k.shape
    hdv = v.shape[-1]  # may differ from hd (MLA: k is nope+rope, v is dv)
    g = h // hkv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    cq = min(cq, sq)
    ck = min(ck, sk)
    pad_q = (-sq) % cq
    pad_k = (-sk) % ck
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // cq, kp.shape[1] // ck

    # (nq, B, cq, Hkv, g, hd)
    qc = qp.reshape(b, nq, cq, hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = kp.reshape(b, nk, ck, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, ck, hkv, hdv).transpose(1, 0, 2, 3, 4)

    kpos_all = jnp.arange(nk * ck)

    def q_chunk(args):
        qi, qblk = args  # qblk (B, cq, Hkv, g, hd)
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def tile(kj, kblk, vblk, m, l, acc):
            kpos = kj * ck + jnp.arange(ck)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk",
                qblk.astype(jnp.float32),
                kblk.astype(jnp.float32),
            ) * scale  # (B, Hkv, g, cq, ck)
            valid = kpos[None, :] < sk
            if causal:
                valid = valid & (qpos[:, None] >= kpos[None, :])
            if window > 0:
                valid = valid & (qpos[:, None] - kpos[None, :] < window)
            s = jnp.where(valid[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = p * valid[None, None, None].astype(jnp.float32)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
            acc_new = acc * alpha[..., None] + pv
            return m_new, l_new, acc_new

        m0 = jnp.full((b, hkv, g, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, hdv), jnp.float32)

        if skip_masked_chunks and (causal or window > 0):
            # dynamic loop bounds: only causally/window-reachable k-chunks
            q_hi = q_offset + qi * cq + cq  # max qpos in this chunk + 1
            hi = jnp.minimum(nk, pl_cdiv(q_hi, ck)) if causal else nk
            if window > 0:
                q_lo = q_offset + qi * cq
                lo = jnp.maximum(0, (q_lo - window + 1) // ck)
            else:
                lo = jnp.zeros((), jnp.int32)

            def body(j, carry):
                m, l, acc = carry
                kblk = jax.lax.dynamic_index_in_dim(kc, j, 0, False)
                vblk = jax.lax.dynamic_index_in_dim(vc, j, 0, False)
                return tile(j, kblk, vblk, m, l, acc)

            m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
        else:
            def k_step(carry, inp):
                kj, kblk, vblk = inp
                return tile(kj, kblk, vblk, *carry), None

            (m, l, acc), _ = jax.lax.scan(
                k_step, (m0, l0, a0), (jnp.arange(nk), kc, vc)
            )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, Hkv, g, cq, hdv) -> (B, cq, Hkv, g, hdv)
        return out.transpose(0, 3, 1, 2, 4)

    outs = jax.lax.map(q_chunk, (jnp.arange(nq), qc))  # (nq, B, cq, Hkv, g, hdv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * cq, h, hdv)
    return out[:, :sq].astype(q.dtype)


# ------------------------------------------------------------------ decode
def decode_attention(
    q: jnp.ndarray,        # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S_max, Hkv, hd)
    v_cache: jnp.ndarray,
    length: jnp.ndarray,   # () int32 — #valid cache entries incl. this token
    window: int = 0,
) -> jnp.ndarray:
    b, _, h, hd = q.shape
    s_max = k_cache.shape[1]
    hkv = k_cache.shape[2]
    hdv = v_cache.shape[-1]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = q.reshape(b, 1, hkv, g, hd)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale  # (B, Hkv, g, 1, S_max)
    kpos = jnp.arange(s_max)
    valid = kpos < length
    if window > 0:
        valid = valid & (length - 1 - kpos < window)
    s = jnp.where(valid[None, None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hdv).astype(q.dtype)


# --------------------------------------------------------------------- MLA
def init_mla(key, cfg: ModelConfig, n_layers: int, dtype) -> Tuple[Dict, Dict]:
    """DeepSeek-V2 Multi-head Latent Attention parameters."""
    d = cfg.d_model
    h = cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (n_layers, d, h, dn + dr), in_axis=1, dtype=dtype),
        "w_dkv": dense_init(ks[1], (n_layers, d, r + dr), in_axis=1, dtype=dtype),
        "ckv_norm": jnp.zeros((n_layers, r), dtype),
        "w_uk": dense_init(ks[2], (n_layers, r, h, dn), in_axis=1, dtype=dtype),
        "w_uv": dense_init(ks[3], (n_layers, r, h, dv), in_axis=1, dtype=dtype),
        "wo": dense_init(ks[4], (n_layers, h, dv, d), in_axis=2, dtype=dtype),
    }
    s = {
        "wq": ("stack", "fsdp", "heads", None),
        "w_dkv": ("stack", "fsdp", None),
        "ckv_norm": ("stack", None),
        "w_uk": ("stack", "fsdp", "heads", None),
        "w_uv": ("stack", "fsdp", "heads", None),
        "wo": ("stack", "heads", None, "fsdp"),
    }
    return p, s


def mla_project(
    pl: Dict, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q (B,S,H,dn+dr), c_kv (B,S,r), k_rope (B,S,dr), v-side
    expansion is done by :func:`mla_expand_kv` so decode can cache the
    *compressed* latent (the MLA memory win)."""
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    r = cfg.kv_lora_rank
    q = jnp.einsum("bsd,dhk->bshk", x, pl["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    ckv_full = jnp.einsum("bsd,dr->bsr", x, pl["w_dkv"].astype(x.dtype))
    c_kv, k_rope = ckv_full[..., :r], ckv_full[..., r:]
    c_kv = rmsnorm(c_kv, pl["ckv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q, c_kv, k_rope


def mla_expand_kv(
    pl: Dict, c_kv: jnp.ndarray, k_rope: jnp.ndarray, x_dtype
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """c_kv (B,S,r), k_rope (B,S,dr) -> k (B,S,H,dn+dr), v (B,S,H,dv)."""
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, pl["w_uk"].astype(x_dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, pl["w_uv"].astype(x_dtype))
    h = k_nope.shape[2]
    k_rope_b = jnp.broadcast_to(
        k_rope[:, :, None, :], (*k_nope.shape[:2], h, k_rope.shape[-1])
    )
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


# ------------------------------------------------------------- cross-attn
def init_cross_attention(key, cfg: ModelConfig, n_layers: int, dtype):
    """Cross-attention (VLM image layers / enc-dec): q from decoder stream,
    kv from frozen context states (vision embeddings / encoder output)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (n_layers, d, nq, hd), in_axis=1, dtype=dtype),
        "wk": dense_init(ks[1], (n_layers, d, nkv, hd), in_axis=1, dtype=dtype),
        "wv": dense_init(ks[2], (n_layers, d, nkv, hd), in_axis=1, dtype=dtype),
        "wo": dense_init(ks[3], (n_layers, nq, hd, d), in_axis=2, dtype=dtype),
        "gate": jnp.zeros((n_layers,), dtype),  # tanh-gated residual (llama-vision)
    }
    s = {
        "wq": ("stack", "fsdp", "heads", None),
        "wk": ("stack", "fsdp", "kv_heads", None),
        "wv": ("stack", "fsdp", "kv_heads", None),
        "wo": ("stack", "heads", None, "fsdp"),
        "gate": ("stack",),
    }
    return p, s


def cross_attention(
    pl: Dict, x: jnp.ndarray, context: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """x (B,S,D) attends over context (B,Sc,D); no mask, no rope."""
    q = jnp.einsum("bsd,dhk->bshk", x, pl["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", context, pl["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", context, pl["wv"].astype(x.dtype))
    out = flash_attention(q, k, v, causal=False)
    out = jnp.einsum("bshk,hkd->bsd", out, pl["wo"].astype(x.dtype))
    return jnp.tanh(pl["gate"]).astype(x.dtype) * out
