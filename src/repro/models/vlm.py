"""Vision-language decoder (llama-3.2-vision family backbone).

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (B, vision_tokens, d_model); the
vision encoder itself is out of scope.  The language backbone is a
decoder-only transformer in which every ``cross_attn_every``-th layer
carries an additional tanh-gated cross-attention sub-layer over the
vision context (llama-vision style).

Scanned as super-blocks of ``cross_attn_every`` layers: (every-1) pure
self-attn layers + 1 cross+self layer, so the HLO stays O(1) in depth.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.shardctx import constrain

Params = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class VisionLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.cross_attn_every > 1
        assert cfg.n_layers % cfg.cross_attn_every == 0
        self.cfg = cfg
        self.n_super = cfg.n_layers // cfg.cross_attn_every
        self.n_self = cfg.cross_attn_every - 1  # self-only layers per block

    def init(self, key) -> Params:
        cfg = self.cfg
        pd = _dtype(cfg.param_dtype)
        ks = jax.random.split(key, 5)
        emb, emb_s = L.init_embed(ks[0], cfg.vocab_size, cfg.d_model, pd)
        n_total = self.n_super * cfg.cross_attn_every
        att, att_s = attn.init_attention(ks[1], cfg, n_total, pd)
        att = jax.tree.map(
            lambda a: a.reshape(self.n_super, cfg.cross_attn_every, *a.shape[1:]),
            att,
        )
        att_s = {k: ("stack", "stack") + tuple(v[1:]) for k, v in att_s.items()}
        xatt, xatt_s = attn.init_cross_attention(ks[2], cfg, self.n_super, pd)
        mlp, mlp_s = L.init_mlp(ks[3], n_total, cfg.d_model, cfg.d_ff, pd)
        mlp = jax.tree.map(
            lambda a: a.reshape(self.n_super, cfg.cross_attn_every, *a.shape[1:]),
            mlp,
        )
        mlp_s = {k: ("stack", "stack") + tuple(v[1:]) for k, v in mlp_s.items()}
        ce = cfg.cross_attn_every
        self._specs = {
            "embed": emb_s, "attn": att_s, "xattn": xatt_s, "mlp": mlp_s,
            "ln1": ("stack", None, None), "ln2": ("stack", None, None),
            "ln_x": ("stack", None), "ln_f": (None,),
        }
        return {
            "embed": emb,
            "attn": att,
            "xattn": xatt,
            "mlp": mlp,
            "ln1": jnp.zeros((self.n_super, ce, cfg.d_model), pd),
            "ln2": jnp.zeros((self.n_super, ce, cfg.d_model), pd),
            "ln_x": jnp.zeros((self.n_super, cfg.d_model), pd),
            "ln_f": jnp.zeros((cfg.d_model,), pd),
        }

    def param_specs(self) -> Dict:
        if not hasattr(self, "_specs"):
            jax.eval_shape(
                self.init, jax.random.PRNGKey(0)
            )
        return self._specs

    def _maybe_remat(self, fn):
        if self.cfg.remat:
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return fn

    def _self_layer(self, pl_attn, ln1, ln2, pl_mlp, x, positions,
                    decode_ctx=None, skip_chunks=False):
        cfg = self.cfg
        h = L.rmsnorm(x, ln1, cfg.norm_eps)
        q, k, v = attn.qkv_project(pl_attn, h, cfg, positions)
        if decode_ctx is None:
            o = attn.flash_attention(q, k, v, causal=True,
                                     skip_masked_chunks=skip_chunks)
            new_kv = (k, v)
        else:
            k_cache, v_cache, pos = decode_ctx
            k_c = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0)
            )
            v_c = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0)
            )
            o = attn.decode_attention(q, k_c, v_c, pos + 1)
            new_kv = (k_c, v_c)
        o = jnp.einsum("bshk,hkd->bsd", o, pl_attn["wo"].astype(x.dtype))
        x = x + o
        h = L.rmsnorm(x, ln2, cfg.norm_eps)
        return x + L.swiglu_mlp(pl_mlp, h), new_kv

    def forward(
        self, params: Params, tokens: jnp.ndarray, vision: jnp.ndarray
    ) -> jnp.ndarray:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        vision = vision.astype(cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        stacked = {
            "attn": params["attn"], "xattn": params["xattn"], "mlp": params["mlp"],
            "ln1": params["ln1"], "ln2": params["ln2"], "ln_x": params["ln_x"],
        }

        def super_block(x, pl):
            # gated cross-attention sub-layer first (llama-vision ordering)
            h = L.rmsnorm(x, pl["ln_x"], cfg.norm_eps)
            x = x + attn.cross_attention(pl["xattn"], h, vision, cfg)
            for j in range(cfg.cross_attn_every):
                x, _ = self._self_layer(
                    jax.tree.map(lambda a: a[j], pl["attn"]),
                    pl["ln1"][j], pl["ln2"][j],
                    jax.tree.map(lambda a: a[j], pl["mlp"]),
                    x, positions,
                )
            return constrain(x, ("batch", None, None))

        fn = lambda x, pl: (self._maybe_remat(super_block)(x, pl), None)  # noqa: E731
        x, _ = jax.lax.scan(fn, x, stacked)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.unembed(params["embed"], x)

    def loss_fn(self, params: Params, batch: Dict) -> jnp.ndarray:
        logits = self.forward(params, batch["tokens"], batch["vision_embeds"])
        return L.softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------ serving
    def cache_specs(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        hd = cfg.resolved_head_dim
        ce = cfg.cross_attn_every
        sv = cfg.vision_tokens
        return {
            "k": jax.ShapeDtypeStruct(
                (self.n_super, ce, batch, max_len, cfg.n_kv_heads, hd), cd
            ),
            "v": jax.ShapeDtypeStruct(
                (self.n_super, ce, batch, max_len, cfg.n_kv_heads, hd), cd
            ),
            # vision K/V are static per request; cached once at prefill
            "xk": jax.ShapeDtypeStruct(
                (self.n_super, batch, sv, cfg.n_kv_heads, hd), cd
            ),
            "xv": jax.ShapeDtypeStruct(
                (self.n_super, batch, sv, cfg.n_kv_heads, hd), cd
            ),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_logical_specs(self) -> Dict:
        return {
            "k": ("stack", None, "batch", "seq", "kv_heads", None),
            "v": ("stack", None, "batch", "seq", "kv_heads", None),
            "xk": ("stack", "batch", "seq", "kv_heads", None),
            "xv": ("stack", "batch", "seq", "kv_heads", None),
            "len": (),
        }

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def prefill(
        self, params: Params, tokens: jnp.ndarray, vision: jnp.ndarray
    ) -> Tuple:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        vision = vision.astype(cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        stacked = {
            "attn": params["attn"], "xattn": params["xattn"], "mlp": params["mlp"],
            "ln1": params["ln1"], "ln2": params["ln2"], "ln_x": params["ln_x"],
        }

        def super_block(x, pl):
            h = L.rmsnorm(x, pl["ln_x"], cfg.norm_eps)
            xk = jnp.einsum(
                "bsd,dhk->bshk", vision, pl["xattn"]["wk"].astype(x.dtype)
            )
            xv = jnp.einsum(
                "bsd,dhk->bshk", vision, pl["xattn"]["wv"].astype(x.dtype)
            )
            q = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"].astype(x.dtype))
            o = attn.flash_attention(q, xk, xv, causal=False)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["xattn"]["wo"].astype(x.dtype))
            x = x + jnp.tanh(pl["xattn"]["gate"]).astype(x.dtype) * o
            ks, vs = [], []
            for j in range(cfg.cross_attn_every):
                x, (k, v) = self._self_layer(
                    jax.tree.map(lambda a: a[j], pl["attn"]),
                    pl["ln1"][j], pl["ln2"][j],
                    jax.tree.map(lambda a: a[j], pl["mlp"]),
                    x, positions, skip_chunks=True,
                )
                ks.append(k)
                vs.append(v)
            return x, {"k": jnp.stack(ks), "v": jnp.stack(vs),
                       "xk": xk, "xv": xv}

        def body(carry, pl):
            return self._maybe_remat(super_block)(carry, pl)

        x, caches = jax.lax.scan(body, x, stacked)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x[:, -1:])
        caches["len"] = jnp.asarray(s, jnp.int32)
        return logits, caches

    def decode_step(
        self, params: Params, tokens: jnp.ndarray, cache: Dict
    ) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b = tokens.shape[0]
        pos = cache["len"]
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(pos[None], (b, 1))
        stacked = {
            "attn": params["attn"], "xattn": params["xattn"], "mlp": params["mlp"],
            "ln1": params["ln1"], "ln2": params["ln2"], "ln_x": params["ln_x"],
        }
        layer_cache = {k: cache[k] for k in ("k", "v", "xk", "xv")}

        def body(x, inp):
            pl, lc = inp
            h = L.rmsnorm(x, pl["ln_x"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"].astype(x.dtype))
            sv = lc["xk"].shape[1]
            o = attn.decode_attention(q, lc["xk"], lc["xv"], jnp.asarray(sv))
            o = jnp.einsum("bshk,hkd->bsd", o, pl["xattn"]["wo"].astype(x.dtype))
            x = x + jnp.tanh(pl["xattn"]["gate"]).astype(x.dtype) * o
            new_k, new_v = [], []
            for j in range(cfg.cross_attn_every):
                x, (k_c, v_c) = self._self_layer(
                    jax.tree.map(lambda a: a[j], pl["attn"]),
                    pl["ln1"][j], pl["ln2"][j],
                    jax.tree.map(lambda a: a[j], pl["mlp"]),
                    x, positions,
                    decode_ctx=(lc["k"][j], lc["v"][j], pos),
                )
                new_k.append(k_c)
                new_v.append(v_c)
            return x, {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                       "xk": lc["xk"], "xv": lc["xv"]}

        x, new_cache = jax.lax.scan(body, x, (stacked, layer_cache))
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x)
        new_cache["len"] = pos + 1
        return logits, new_cache
