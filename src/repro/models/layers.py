"""Shared neural building blocks: norms, SwiGLU MLP, RoPE, init helpers.

All parameters are plain pytrees (dicts of jnp arrays).  Every param
tensor has a parallel *logical spec* (tuple of logical axis names) used
by the launcher to build in_shardings; layer-stacked params carry a
leading "stack" axis (scan-over-layers keeps the HLO O(1) in depth).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.shardctx import constrain

Params = Dict[str, Any]
Specs = Dict[str, Any]


# ------------------------------------------------------------------- init
def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32, scale=1.0):
    """LeCun-normal on the reduction dim."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return (scale / jnp.sqrt(fan_in)) * jax.random.normal(key, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


# ------------------------------------------------------------------- norms
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def head_rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm: RMSNorm over the head_dim axis (qwen3)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# -------------------------------------------------------------------- MLP
def init_mlp(key, n_layers: int, d_model: int, d_ff: int, dtype) -> Tuple[Params, Specs]:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_gate": dense_init(k1, (n_layers, d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (n_layers, d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (n_layers, d_ff, d_model), dtype=dtype),
    }
    s = {
        "w_gate": ("stack", "fsdp", "mlp"),
        "w_up": ("stack", "fsdp", "mlp"),
        "w_down": ("stack", "mlp", "fsdp"),
    }
    return p, s


def swiglu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, D) -> (B, S, D) with hidden sharded over tp."""
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = constrain(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x (..., S, n_heads, head_dim), positions (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- embed
def init_embed(key, vocab: int, d_model: int, dtype) -> Tuple[Params, Specs]:
    p = {"embedding": embed_init(key, (vocab, d_model), dtype)}
    s = {"embedding": ("vocab", "fsdp")}
    return p, s


def embed_tokens(p: Params, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    out = p["embedding"].astype(compute_dtype)[tokens]
    return constrain(out, ("batch", None, None))


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("bsd,vd->bsv", x, p["embedding"].astype(x.dtype))
    return constrain(logits, ("batch", None, "vocab"))


# ------------------------------------------------------------------- loss
def softmax_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Mean token NLL; logits (B, S, V) possibly vocab-sharded."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
