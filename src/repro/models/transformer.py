"""Decoder-only transformer LM (dense GQA / MoE / MLA families).

Covers grok-1, deepseek-v2-lite (MLA+MoE), granite-3, qwen2, qwen3,
starcoder2.  Layers are *scanned* (params stacked on a leading "stack"
axis) so the HLO is O(1) in depth; the per-layer body is rematerialized
(``jax.checkpoint``) under cfg.remat.

Entry points (used by train/serve/dry-run):
    init(key) / param_specs()
    loss_fn(params, batch)                      train_step target
    prefill(params, tokens) -> (logits, cache)  inference-prefill
    decode_step(params, tokens, cache)          inference-decode
    init_cache(batch, max_len) / cache_specs()
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models.shardctx import constrain

Params = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params
    def init(self, key) -> Params:
        cfg = self.cfg
        pd = _dtype(cfg.param_dtype)
        ks = jax.random.split(key, 4)
        emb, emb_s = L.init_embed(ks[0], cfg.vocab_size, cfg.d_model, pd)
        if cfg.mla:
            att, att_s = attn.init_mla(ks[1], cfg, cfg.n_layers, pd)
        else:
            att, att_s = attn.init_attention(ks[1], cfg, cfg.n_layers, pd)
        if cfg.moe:
            ffn, ffn_s = moe_mod.init_moe(ks[2], cfg, cfg.n_layers, pd)
        else:
            ffn, ffn_s = L.init_mlp(ks[2], cfg.n_layers, cfg.d_model, cfg.d_ff, pd)
        params = {
            "embed": emb,
            "attn": att,
            "ffn": ffn,
            "ln1": jnp.zeros((cfg.n_layers, cfg.d_model), pd),
            "ln2": jnp.zeros((cfg.n_layers, cfg.d_model), pd),
            "ln_f": jnp.zeros((cfg.d_model,), pd),
        }
        self._specs = {
            "embed": emb_s,
            "attn": att_s,
            "ffn": ffn_s,
            "ln1": ("stack", None),
            "ln2": ("stack", None),
            "ln_f": (None,),
        }
        return params

    def param_specs(self) -> Dict:
        if not hasattr(self, "_specs"):
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._specs

    # ------------------------------------------------------------ forward
    def _layer(self, pl: Params, x, positions, window: int):
        cfg = self.cfg
        h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
        if cfg.mla:
            q, c_kv, k_rope = attn.mla_project(pl["attn"], h, cfg, positions)
            k, v = attn.mla_expand_kv(pl["attn"], c_kv, k_rope, h.dtype)
            o = attn.flash_attention(q, k, v, causal=True, window=window)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
        else:
            q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
            o = attn.flash_attention(q, k, v, causal=True, window=window)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
        x = x + o
        h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
        if cfg.moe:
            f = moe_mod.moe_ffn(pl["ffn"], h, cfg)
        else:
            f = L.swiglu_mlp(pl["ffn"], h)
        x = x + f
        return constrain(x, ("batch", None, None))

    def forward(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        window = cfg.local_window

        stacked = {
            "attn": params["attn"], "ffn": params["ffn"],
            "ln1": params["ln1"], "ln2": params["ln2"],
        }

        if cfg.scan_layers:
            fn = lambda x, pl: (  # noqa: E731
                self._maybe_remat(
                    lambda xx, pp: self._layer(pp, xx, positions, window)
                )(x, pl),
                None,
            )
            x, _ = jax.lax.scan(fn, x, stacked)
        else:
            for i in range(cfg.n_layers):
                pl = jax.tree.map(lambda a: a[i], stacked)
                x = self._layer(pl, x, positions, window)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.unembed(params["embed"], x)

    def _maybe_remat(self, fn):
        if self.cfg.remat:
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return fn

    def loss_fn(self, params: Params, batch: Dict) -> jnp.ndarray:
        logits = self.forward(params, batch["tokens"])
        return L.softmax_cross_entropy(
            logits, batch["labels"], batch.get("mask")
        )

    # ------------------------------------------------------------ serving
    def cache_specs(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        if cfg.mla:
            r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
            return {
                "ckv": jax.ShapeDtypeStruct((cfg.n_layers, batch, max_len, r), cd),
                "krope": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, max_len, dr), cd
                ),
                "len": jax.ShapeDtypeStruct((), jnp.int32),
            }
        hd = cfg.resolved_head_dim
        return {
            "k": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), cd
            ),
            "v": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), cd
            ),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_logical_specs(self) -> Dict:
        if self.cfg.mla:
            return {
                "ckv": ("stack", "batch", "seq", None),
                "krope": ("stack", "batch", "seq", None),
                "len": (),
            }
        return {
            "k": ("stack", "batch", "seq", "kv_heads", None),
            "v": ("stack", "batch", "seq", "kv_heads", None),
            "len": (),
        }

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def prefill(self, params: Params, tokens: jnp.ndarray) -> Tuple:
        """Forward over the prompt; returns (last-token logits, full cache)."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        stacked = {
            "attn": params["attn"], "ffn": params["ffn"],
            "ln1": params["ln1"], "ln2": params["ln2"],
        }

        def layer_with_cache(x, pl):
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            if cfg.mla:
                q, c_kv, k_rope = attn.mla_project(pl["attn"], h, cfg, positions)
                k, v = attn.mla_expand_kv(pl["attn"], c_kv, k_rope, h.dtype)
                cache_out = {"ckv": c_kv, "krope": k_rope}
            else:
                q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
                cache_out = {"k": k, "v": v}
            o = attn.flash_attention(
                q, k, v, causal=True, window=cfg.local_window,
                skip_masked_chunks=True,  # inference: no grad (§Perf H3)
            )
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
            x = x + o
            h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
            f = moe_mod.moe_ffn(pl["ffn"], h, cfg) if cfg.moe else L.swiglu_mlp(
                pl["ffn"], h
            )
            return x + f, cache_out

        def body(carry, pl):
            return self._maybe_remat(layer_with_cache)(carry, pl)

        x, caches = jax.lax.scan(body, x, stacked)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x[:, -1:])
        caches["len"] = jnp.asarray(s, jnp.int32)
        return logits, caches

    def decode_step(
        self, params: Params, tokens: jnp.ndarray, cache: Dict
    ) -> Tuple[jnp.ndarray, Dict]:
        """tokens (B, 1); cache from prefill/init. Appends one position."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b = tokens.shape[0]
        pos = cache["len"]
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(pos[None], (b, 1))
        stacked = {
            "attn": params["attn"], "ffn": params["ffn"],
            "ln1": params["ln1"], "ln2": params["ln2"],
        }
        layer_cache = {k: v for k, v in cache.items() if k != "len"}

        def body(x, inp):
            pl, lc = inp
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            if cfg.mla:
                q, c_kv, k_rope = attn.mla_project(pl["attn"], h, cfg, positions)
                ckv_c = jax.lax.dynamic_update_slice(
                    lc["ckv"], c_kv.astype(lc["ckv"].dtype), (0, pos, 0)
                )
                kr_c = jax.lax.dynamic_update_slice(
                    lc["krope"], k_rope.astype(lc["krope"].dtype), (0, pos, 0)
                )
                k, v = attn.mla_expand_kv(pl["attn"], ckv_c, kr_c, h.dtype)
                o = attn.decode_attention(
                    q, k, v, pos + 1, window=cfg.local_window
                )
                new_lc = {"ckv": ckv_c, "krope": kr_c}
            else:
                q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
                k_c = jax.lax.dynamic_update_slice(
                    lc["k"], k.astype(lc["k"].dtype), (0, pos, 0, 0)
                )
                v_c = jax.lax.dynamic_update_slice(
                    lc["v"], v.astype(lc["v"].dtype), (0, pos, 0, 0)
                )
                o = attn.decode_attention(
                    q, k_c, v_c, pos + 1, window=cfg.local_window
                )
                new_lc = {"k": k_c, "v": v_c}
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
            x = x + o
            h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
            f = moe_mod.moe_ffn(pl["ffn"], h, cfg) if cfg.moe else L.swiglu_mlp(
                pl["ffn"], h
            )
            return x + f, new_lc

        x, new_cache = jax.lax.scan(body, x, (stacked, layer_cache))
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x)
        new_cache["len"] = pos + 1
        return logits, new_cache
