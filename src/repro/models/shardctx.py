"""Logical-axis sharding context.

Model code annotates activations/params with *logical* axis names
("batch", "heads", "mlp", ...).  The launcher installs a mesh + a
logical->mesh rule set; outside any context (CPU tests) every constraint
is a no-op, so model code never mentions physical axes.

Train rules (MaxText-style):  batch over (pod, data); weights FSDP-sharded
over "data" on their reduction dim and tensor-parallel over "model" on
heads/mlp/vocab/expert dims (ZeRO-3 falls out of XLA SPMD).
Serve rules: weights replicated over "data" (no per-token all-gathers at
decode), KV cache batch-sharded over (pod, data) and head-sharded over
"model".
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Logical = Union[str, None]
_STATE = threading.local()


def train_rules(multi_pod: bool) -> Dict[str, Optional[Tuple[str, ...]]]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "fsdp": ("data",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
    }


def serve_rules(multi_pod: bool) -> Dict[str, Optional[Tuple[str, ...]]]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "fsdp": None,  # replicate weights across data at decode
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        # KV caches: when kv_heads cannot divide the model axis (GQA with
        # few KV heads), the cache SEQUENCE dim takes the model axis —
        # partial softmax over sharded keys costs tiny (B,H,1)-sized
        # reductions instead of all-gathering the multi-GB cache.
        "seq": ("model",),
    }


def set_context(mesh: Optional[Mesh], rules: Optional[Dict]) -> None:
    _STATE.mesh = mesh
    _STATE.rules = rules or {}


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Dict):
    prev = (getattr(_STATE, "mesh", None), getattr(_STATE, "rules", {}))
    set_context(mesh, rules)
    try:
        with mesh:
            yield
    finally:
        set_context(*prev)


def resolve(
    logical: Sequence[Logical], shape: Optional[Sequence[int]] = None
) -> P:
    """Map logical axis names to a PartitionSpec under the active rules.

    When ``shape`` is given, axes whose mesh extent does not divide the
    dim are dropped (e.g. kv_heads=8 over model=16, or batch=1 over data)
    — GSPMD would otherwise reject the annotation.  Dropped constraints
    mean replication on that dim, which is always semantically safe.
    """
    rules = getattr(_STATE, "rules", {})
    mesh = get_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    out = []
    for i, name in enumerate(logical):
        axes = rules.get(name) if name else None
        if not axes:
            out.append(None)
            continue
        if shape is not None and sizes:
            extent = 1
            for a in axes:
                extent *= sizes.get(a, 1)
            if shape[i] % extent != 0:
                out.append(None)
                continue
        out.append(axes[0] if len(axes) == 1 else tuple(axes))
    return P(*out)


def constrain(x, logical: Sequence[Logical]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(logical, x.shape))
    )


def sharding_for(
    logical: Sequence[Logical], shape: Optional[Sequence[int]] = None
) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(logical, shape))
