"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Gated diagonal linear recurrence over time:

    r_t = σ(W_r x_t + b_r)                    recurrence gate
    i_t = σ(W_i x_t + b_i)                    input gate
    a_t = exp(-c · softplus(Λ) · r_t)         per-channel decay, c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses ``jax.lax.associative_scan`` (parallel prefix over
the linear recurrence) — O(log T) depth, no O(T²) memory; this is the
sub-quadratic path that makes long_500k viable for the hybrid arch.
Decode is the O(1) per-step update on an (B, width) state.

The full Griffin *recurrent block* wraps the LRU with the gated two-branch
structure: [linear -> GeLU gate] ⊙ [linear -> causal conv(4) -> RG-LRU],
followed by a down projection.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig, n_layers: int, dtype) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    p = {
        "w_gate_branch": dense_init(ks[0], (n_layers, d, w), in_axis=1, dtype=dtype),
        "w_rec_branch": dense_init(ks[1], (n_layers, d, w), in_axis=1, dtype=dtype),
        "conv_w": dense_init(ks[2], (n_layers, cfg.conv_kernel, w), in_axis=1, dtype=dtype),
        "conv_b": jnp.zeros((n_layers, w), dtype),
        "w_r": dense_init(ks[3], (n_layers, w, w), in_axis=1, dtype=dtype),
        "b_r": jnp.zeros((n_layers, w), jnp.float32),
        "w_i": dense_init(ks[4], (n_layers, w, w), in_axis=1, dtype=dtype),
        "b_i": jnp.zeros((n_layers, w), jnp.float32),
        "lam": jnp.full((n_layers, w), 2.0, jnp.float32),  # softplus(2)≈2.1
        "w_out": dense_init(ks[5], (n_layers, w, d), in_axis=1, dtype=dtype),
    }
    s = {
        "w_gate_branch": ("stack", "fsdp", "mlp"),
        "w_rec_branch": ("stack", "fsdp", "mlp"),
        "conv_w": ("stack", None, "mlp"),
        "conv_b": ("stack", "mlp"),
        "w_r": ("stack", "fsdp", "mlp"),
        "b_r": ("stack", "mlp"),
        "w_i": ("stack", "fsdp", "mlp"),
        "b_i": ("stack", "mlp"),
        "lam": ("stack", "mlp"),
        "w_out": ("stack", "mlp", "fsdp"),
    }
    return p, s


def _gates(pl: Dict, u: jnp.ndarray):
    """u (B, T, W) -> per-step decay a_t (f32) and gated input.

    The W×W gate matmuls run in the compute dtype (bf16 in production —
    halves their gradient all-reduce bytes, §Perf note); the recurrence
    math (sigmoid/softplus/exp and the scan itself) stays float32 — the
    LRU decay is precision-sensitive.
    """
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(
        jnp.einsum("btw,wv->btv", u, pl["w_r"].astype(u.dtype))
        .astype(jnp.float32) + pl["b_r"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("btw,wv->btv", u, pl["w_i"].astype(u.dtype))
        .astype(jnp.float32) + pl["b_i"]
    )
    log_a = -_C * jax.nn.softplus(pl["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, gated


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, h0=None) -> jnp.ndarray:
    """Parallel prefix for h_t = a_t h_{t-1} + b_t over axis 1."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(pl: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Griffin recurrent block for training/prefill. x (B,T,D) -> (B,T,D)."""
    gate = jax.nn.gelu(
        jnp.einsum("btd,dw->btw", x, pl["w_gate_branch"].astype(x.dtype))
    )
    u = jnp.einsum("btd,dw->btw", x, pl["w_rec_branch"].astype(x.dtype))
    # causal depthwise conv(K)
    k = cfg.conv_kernel
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    u = sum(
        pad[:, i : i + u.shape[1], :] * pl["conv_w"][i][None, None, :].astype(x.dtype)
        for i in range(k)
    ) + pl["conv_b"][None, None, :].astype(x.dtype)
    a, gated = _gates(pl, u)
    h = rglru_scan(a, gated).astype(x.dtype)
    out = h * gate
    return jnp.einsum("btw,wd->btd", out, pl["w_out"].astype(x.dtype))


def rglru_decode_step(
    pl: Dict, x: jnp.ndarray, state: Dict, cfg: ModelConfig
) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. state = {"h": (B, W), "conv": (B, K-1, W)}."""
    gate = jax.nn.gelu(
        jnp.einsum("btd,dw->btw", x, pl["w_gate_branch"].astype(x.dtype))
    )
    u_new = jnp.einsum("btd,dw->btw", x, pl["w_rec_branch"].astype(x.dtype))
    hist = jnp.concatenate([state["conv"], u_new], axis=1)  # (B, K, W)
    u = (
        jnp.einsum("bkw,kw->bw", hist, pl["conv_w"].astype(x.dtype))
        + pl["conv_b"].astype(x.dtype)
    )[:, None, :]
    a, gated = _gates(pl, u)
    h = a[:, 0] * state["h"] + gated[:, 0]
    out = (h[:, None, :].astype(x.dtype)) * gate
    out = jnp.einsum("btw,wd->btd", out, pl["w_out"].astype(x.dtype))
    return out, {"h": h, "conv": hist[:, 1:]}
