"""Architecture zoo: dense GQA / MoE / MLA / SSD / RG-LRU / VLM / enc-dec."""
from repro.models.model import SHAPES, build_model, input_specs, shape_applicable

__all__ = ["SHAPES", "build_model", "input_specs", "shape_applicable"]
