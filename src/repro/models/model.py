"""Model zoo dispatch + per-shape input specs.

``build_model(cfg)`` returns the family implementation; every model
exposes the same surface: init / param_specs / loss_fn / prefill /
decode_step / cache_specs / cache_logical_specs.

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
model input of an (arch × shape) cell — weak-type-correct, shardable, no
device allocation — the dry-run contract.  Modality frontends are STUBS:
VLM cells get precomputed patch embeddings, audio cells get precomputed
frame embeddings, per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.griffin_model import GriffinLM
from repro.models.mamba_model import Mamba2LM
from repro.models.transformer import DecoderLM
from repro.models.vlm import VisionLM

#: assigned input-shape grid (LM shapes: seq_len × global_batch)
SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

#: families with sub-quadratic sequence mixing (run long_500k)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        return GriffinLM(cfg)
    if cfg.family == "vlm":
        return VisionLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    raise KeyError(f"unknown family {cfg.family!r}")


def shape_applicable(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if runnable; otherwise the skip reason (recorded in tables)."""
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return "skip(full-attn): quadratic attention at 524k context"
    return None


def input_specs(
    cfg: ModelConfig, shape: str, batch_override: Optional[int] = None
) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for the step function of (cfg × shape)."""
    sh = SHAPES[shape]
    b = batch_override or sh["batch"]
    s = sh["seq"]
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    model = build_model(cfg)

    if sh["kind"] == "train":
        batch: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.d_model), bf16
            )
        if cfg.family == "audio":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), bf16
            )
        return {"batch": batch}

    if sh["kind"] == "prefill":
        out: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            out["vision"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.d_model), bf16
            )
        if cfg.family == "audio":
            out["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), bf16
            )
        return out

    # decode: one new token against a cache of size seq
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cache": model.cache_specs(b, s),
    }
