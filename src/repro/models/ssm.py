"""Mamba-2 SSD (state-space duality) block — chunked, MXU-friendly.

Selective state space per head h (head dim P, state dim N):

    S_t = a_t · S_{t-1} + (Δ_t x_t) B_tᵀ          (P × N state)
    y_t = S_t C_t + D_h · x_t

with a_t = exp(-exp(A_log_h) · Δ_t), Δ_t = softplus(dt_raw + dt_bias).

Training/prefill uses the chunked SSD algorithm: the sequence is split
into chunks of Q steps; within a chunk the contribution is a masked
(Q × Q) matmul (the "duality" — attention-like, runs on the MXU), and
chunk states are carried by a short lax.scan (T/Q steps).  O(T·Q) time,
O(T) memory — this is the sub-quadratic path that makes long_500k viable.

Decode is the O(1) recurrence on a (B, H, P, N) state cache.
B/C are shared across heads (single group, G=1), as in Mamba-2.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm
from repro.models.shardctx import constrain


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def init_ssm(key, cfg: ModelConfig, n_layers: int, dtype) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    d_in, h, n = ssm_dims(cfg)
    conv_dim = d_in + 2 * n
    ks = jax.random.split(key, 4)
    p = {
        # packs [z gate (d_in), x (d_in), B (n), C (n), dt (h)]
        "in_proj": dense_init(
            ks[0], (n_layers, d, 2 * d_in + 2 * n + h), in_axis=1, dtype=dtype
        ),
        "conv_w": dense_init(
            ks[1], (n_layers, cfg.conv_kernel, conv_dim), in_axis=1, dtype=dtype
        ),
        "conv_b": jnp.zeros((n_layers, conv_dim), dtype),
        "A_log": jnp.zeros((n_layers, h), jnp.float32),
        "D": jnp.ones((n_layers, h), jnp.float32),
        "dt_bias": jnp.zeros((n_layers, h), jnp.float32),
        "out_norm": jnp.zeros((n_layers, d_in), dtype),
        "out_proj": dense_init(ks[2], (n_layers, d_in, d), in_axis=1, dtype=dtype),
    }
    s = {
        "in_proj": ("stack", "fsdp", "mlp"),
        "conv_w": ("stack", None, "mlp"),
        "conv_b": ("stack", "mlp"),
        "A_log": ("stack", None),
        "D": ("stack", None),
        "dt_bias": ("stack", None),
        "out_norm": ("stack", "mlp"),
        "out_proj": ("stack", "mlp", "fsdp"),
    }
    return p, s


def _split_proj(proj: jnp.ndarray, cfg: ModelConfig):
    d_in, h, n = ssm_dims(cfg)
    z = proj[..., :d_in]
    xbc = proj[..., d_in : 2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray):
    """Depthwise causal conv over time. xbc (B, T, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + bias[None, None, :])


def ssd_chunked(
    xh: jnp.ndarray,    # (B, T, H, P)  Δ-scaled inputs  (x̄ = Δ·x)
    la: jnp.ndarray,    # (B, T, H)     log decay  (log a_t, ≤ 0)
    Bm: jnp.ndarray,    # (B, T, N)
    Cm: jnp.ndarray,    # (B, T, N)
    chunk: int,
    s0: jnp.ndarray = None,  # (B, H, P, N) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-parallel SSD. Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    b, t, h, p = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, t)
    pad = (-t) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // q
    xh = xh.reshape(b, nc, q, h, p)
    la = la.reshape(b, nc, q, h)
    Bm = Bm.reshape(b, nc, q, n)
    Cm = Cm.reshape(b, nc, q, n)

    cum = jnp.cumsum(la, axis=2)                      # (B, NC, Q, H) Σ log a
    # intra-chunk: y_i = Σ_{j<=i} (C_i·B_j) exp(cum_i - cum_j) x̄_j
    G = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)         # (B, NC, Q, Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,NC,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    M = G[..., None] * jnp.where(mask[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xh)

    # chunk summaries: S_c = Σ_j exp(cum_Q - cum_j) x̄_j B_jᵀ
    tail = jnp.exp(cum[:, :, -1:, :] - cum)           # (B, NC, Q, H)
    S_c = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", tail, xh, Bm)
    a_chunk = jnp.exp(cum[:, :, -1, :])               # (B, NC, H) total decay

    def chunk_step(s_prev, inp):
        s_c, a_c = inp                                # (B,H,P,N), (B,H)
        s_new = a_c[..., None, None] * s_prev + s_c
        return s_new, s_prev

    if s0 is None:
        s0 = jnp.zeros((b, h, p, n), xh.dtype)
    s_final, s_prevs = jax.lax.scan(
        chunk_step,
        s0,
        (S_c.transpose(1, 0, 2, 3, 4), a_chunk.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)        # (B, NC, H, P, N)

    # inter-chunk: y_i += exp(cum_i) · C_i · S_prev
    y_inter = jnp.einsum(
        "bcih,bcin,bchpn->bcihp", jnp.exp(cum), Cm, s_prevs
    )
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)
    return y[:, :t], s_final


def ssm_block(
    pl: Dict, x: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """Full Mamba-2 mixer for training/prefill. x (B, T, D) -> (B, T, D)."""
    d_in, h, n = ssm_dims(cfg)
    p_dim = cfg.ssm_head_dim
    proj = jnp.einsum("btd,dk->btk", x, pl["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, pl["conv_w"].astype(x.dtype), pl["conv_b"].astype(x.dtype))
    xs = xbc[..., :d_in]
    Bm = xbc[..., d_in : d_in + n].astype(jnp.float32)
    Cm = xbc[..., d_in + n :].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"])  # (B,T,H)
    a = -jnp.exp(pl["A_log"])[None, None, :] * dt                     # log decay
    xh = xs.reshape(*xs.shape[:2], h, p_dim).astype(jnp.float32)
    xh_bar = xh * dt[..., None]

    y, _ = ssd_chunked(xh_bar, a, Bm, Cm, cfg.ssm_chunk)
    y = y + pl["D"][None, None, :, None] * xh
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), pl["out_norm"], cfg.norm_eps)  # gated norm
    return jnp.einsum("btk,kd->btd", y, pl["out_proj"].astype(x.dtype))


# ------------------------------------------------------------------ decode
def ssm_decode_step(
    pl: Dict,
    x: jnp.ndarray,          # (B, 1, D)
    state: Dict,             # {"s": (B,H,P,N), "conv": (B, K-1, C)}
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict]:
    d_in, h, n = ssm_dims(cfg)
    p_dim = cfg.ssm_head_dim
    k = cfg.conv_kernel
    proj = jnp.einsum("btd,dk->btk", x, pl["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, cfg)

    conv_hist = jnp.concatenate([state["conv"], xbc], axis=1)  # (B, K, C)
    xbc_t = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_hist, pl["conv_w"].astype(x.dtype))
        + pl["conv_b"].astype(x.dtype)
    )[:, None, :]
    new_conv = conv_hist[:, 1:]

    xs = xbc_t[..., :d_in]
    Bm = xbc_t[..., d_in : d_in + n].astype(jnp.float32)[:, 0]     # (B,N)
    Cm = xbc_t[..., d_in + n :].astype(jnp.float32)[:, 0]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + pl["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(pl["A_log"])[None, :] * dt)                        # (B,H)
    xh = xs[:, 0].reshape(-1, h, p_dim).astype(jnp.float32)                 # (B,H,P)
    xh_bar = xh * dt[..., None]

    s = state["s"]
    s = a[..., None, None] * s + jnp.einsum("bhp,bn->bhpn", xh_bar, Bm)
    y = jnp.einsum("bhpn,bn->bhp", s, Cm) + pl["D"][None, :, None] * xh
    y = y.reshape(-1, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), pl["out_norm"], cfg.norm_eps)
    out = jnp.einsum("btk,kd->btd", y, pl["out_proj"].astype(x.dtype))
    return out, {"s": s, "conv": new_conv}
