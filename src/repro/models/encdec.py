"""Encoder-decoder audio model (Whisper family backbone).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, encoder_seq, d_model).  Encoder layers
are bidirectional self-attn + MLP; decoder layers are causal self-attn +
cross-attn over encoder output + MLP.  Both stacks are scanned.

Decode: self-KV cache grows per token; cross-K/V are computed once at
prefill and cached (static per request).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.shardctx import constrain

Params = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        pd = _dtype(cfg.param_dtype)
        ks = jax.random.split(key, 8)
        emb, emb_s = L.init_embed(ks[0], cfg.vocab_size, cfg.d_model, pd)
        e_att, e_att_s = attn.init_attention(ks[1], cfg, cfg.n_encoder_layers, pd)
        e_mlp, e_mlp_s = L.init_mlp(
            ks[2], cfg.n_encoder_layers, cfg.d_model, cfg.d_ff, pd
        )
        d_att, d_att_s = attn.init_attention(ks[3], cfg, cfg.n_layers, pd)
        d_x, d_x_s = attn.init_cross_attention(ks[4], cfg, cfg.n_layers, pd)
        d_mlp, d_mlp_s = L.init_mlp(ks[5], cfg.n_layers, cfg.d_model, cfg.d_ff, pd)
        self._specs = {
            "embed": emb_s,
            "enc_attn": e_att_s, "enc_mlp": e_mlp_s,
            "enc_ln1": ("stack", None), "enc_ln2": ("stack", None),
            "enc_ln_f": (None,),
            "dec_attn": d_att_s, "dec_xattn": d_x_s, "dec_mlp": d_mlp_s,
            "dec_ln1": ("stack", None), "dec_lnx": ("stack", None),
            "dec_ln2": ("stack", None), "ln_f": (None,),
        }
        z = lambda *shape: jnp.zeros(shape, pd)  # noqa: E731
        return {
            "embed": emb,
            "enc_attn": e_att, "enc_mlp": e_mlp,
            "enc_ln1": z(cfg.n_encoder_layers, cfg.d_model),
            "enc_ln2": z(cfg.n_encoder_layers, cfg.d_model),
            "enc_ln_f": z(cfg.d_model),
            "dec_attn": d_att, "dec_xattn": d_x, "dec_mlp": d_mlp,
            "dec_ln1": z(cfg.n_layers, cfg.d_model),
            "dec_lnx": z(cfg.n_layers, cfg.d_model),
            "dec_ln2": z(cfg.n_layers, cfg.d_model),
            "ln_f": z(cfg.d_model),
        }

    def param_specs(self) -> Dict:
        if not hasattr(self, "_specs"):
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._specs

    def _maybe_remat(self, fn):
        if self.cfg.remat:
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return fn

    # ------------------------------------------------------------- encoder
    def encode(self, params: Params, audio_embeds: jnp.ndarray) -> jnp.ndarray:
        """audio_embeds (B, S_enc, D) — stubbed conv frontend output."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        x = audio_embeds.astype(cd)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        stacked = {
            "attn": params["enc_attn"], "mlp": params["enc_mlp"],
            "ln1": params["enc_ln1"], "ln2": params["enc_ln2"],
        }

        def layer(x, pl):
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
            o = attn.flash_attention(q, k, v, causal=False)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
            x = x + o
            h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
            return x + L.swiglu_mlp(pl["mlp"], h)

        fn = lambda x, pl: (self._maybe_remat(layer)(x, pl), None)  # noqa: E731
        x, _ = jax.lax.scan(fn, x, stacked)
        return L.rmsnorm(x, params["enc_ln_f"], cfg.norm_eps)

    # ------------------------------------------------------------- decoder
    def _dec_layer(self, pl, x, positions, enc_out, decode_ctx=None):
        cfg = self.cfg
        h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
        if decode_ctx is None:
            o = attn.flash_attention(q, k, v, causal=True)
            new_kv = (k, v)
        else:
            k_cache, v_cache, pos = decode_ctx
            k_c = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0)
            )
            v_c = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0)
            )
            o = attn.decode_attention(q, k_c, v_c, pos + 1)
            new_kv = (k_c, v_c)
        o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
        x = x + o
        h = L.rmsnorm(x, pl["lnx"], cfg.norm_eps)
        x = x + attn.cross_attention(pl["xattn"], h, enc_out, cfg)
        h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
        return x + L.swiglu_mlp(pl["mlp"], h), new_kv

    def forward(
        self, params: Params, tokens: jnp.ndarray, audio_embeds: jnp.ndarray
    ) -> jnp.ndarray:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        enc_out = self.encode(params, audio_embeds)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        stacked = {
            "attn": params["dec_attn"], "xattn": params["dec_xattn"],
            "mlp": params["dec_mlp"], "ln1": params["dec_ln1"],
            "lnx": params["dec_lnx"], "ln2": params["dec_ln2"],
        }

        def layer(x, pl):
            y, _ = self._dec_layer(pl, x, positions, enc_out)
            return constrain(y, ("batch", None, None))

        fn = lambda x, pl: (self._maybe_remat(layer)(x, pl), None)  # noqa: E731
        x, _ = jax.lax.scan(fn, x, stacked)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.unembed(params["embed"], x)

    def loss_fn(self, params: Params, batch: Dict) -> jnp.ndarray:
        logits = self.forward(params, batch["tokens"], batch["audio_embeds"])
        return L.softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------ serving
    def cache_specs(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        hd = cfg.resolved_head_dim
        return {
            "k": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), cd
            ),
            "v": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), cd
            ),
            # cross K/V: static per request, computed at prefill
            "xk": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), cd
            ),
            "xv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), cd
            ),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_logical_specs(self) -> Dict:
        return {
            "k": ("stack", "batch", "seq", "kv_heads", None),
            "v": ("stack", "batch", "seq", "kv_heads", None),
            "xk": ("stack", "batch", "seq", "kv_heads", None),
            "xv": ("stack", "batch", "seq", "kv_heads", None),
            "len": (),
        }

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def prefill(
        self, params: Params, tokens: jnp.ndarray, audio_embeds: jnp.ndarray
    ) -> Tuple:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        enc_out = self.encode(params, audio_embeds)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        stacked = {
            "attn": params["dec_attn"], "xattn": params["dec_xattn"],
            "mlp": params["dec_mlp"], "ln1": params["dec_ln1"],
            "lnx": params["dec_lnx"], "ln2": params["dec_ln2"],
        }

        def layer(x, pl):
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
            o = attn.flash_attention(q, k, v, causal=True,
                                     skip_masked_chunks=True)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
            x = x + o
            h = L.rmsnorm(x, pl["lnx"], cfg.norm_eps)
            xk = jnp.einsum(
                "bsd,dhk->bshk", enc_out, pl["xattn"]["wk"].astype(x.dtype)
            )
            xv = jnp.einsum(
                "bsd,dhk->bshk", enc_out, pl["xattn"]["wv"].astype(x.dtype)
            )
            q2 = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"].astype(x.dtype))
            o = attn.flash_attention(q2, xk, xv, causal=False)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["xattn"]["wo"].astype(x.dtype))
            x = x + jnp.tanh(pl["xattn"]["gate"]).astype(x.dtype) * o
            h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
            x = x + L.swiglu_mlp(pl["mlp"], h)
            return x, {"k": k, "v": v, "xk": xk, "xv": xv}

        def body(carry, pl):
            return self._maybe_remat(layer)(carry, pl)

        x, caches = jax.lax.scan(body, x, stacked)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x[:, -1:])
        caches["len"] = jnp.asarray(s, jnp.int32)
        return logits, caches

    def decode_step(
        self, params: Params, tokens: jnp.ndarray, cache: Dict
    ) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b = tokens.shape[0]
        pos = cache["len"]
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(pos[None], (b, 1))
        stacked = {
            "attn": params["dec_attn"], "xattn": params["dec_xattn"],
            "mlp": params["dec_mlp"], "ln1": params["dec_ln1"],
            "lnx": params["dec_lnx"], "ln2": params["dec_ln2"],
        }
        layer_cache = {k: cache[k] for k in ("k", "v", "xk", "xv")}

        def body(x, inp):
            pl, lc = inp
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
            k_c = jax.lax.dynamic_update_slice(
                lc["k"], k.astype(lc["k"].dtype), (0, pos, 0, 0)
            )
            v_c = jax.lax.dynamic_update_slice(
                lc["v"], v.astype(lc["v"].dtype), (0, pos, 0, 0)
            )
            o = attn.decode_attention(q, k_c, v_c, pos + 1)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
            x = x + o
            h = L.rmsnorm(x, pl["lnx"], cfg.norm_eps)
            q2 = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"].astype(x.dtype))
            o = attn.decode_attention(
                q2, lc["xk"], lc["xv"], jnp.asarray(lc["xk"].shape[1])
            )
            o = jnp.einsum("bshk,hkd->bsd", o, pl["xattn"]["wo"].astype(x.dtype))
            x = x + jnp.tanh(pl["xattn"]["gate"]).astype(x.dtype) * o
            h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
            x = x + L.swiglu_mlp(pl["mlp"], h)
            return x, {"k": k_c, "v": v_c, "xk": lc["xk"], "xv": lc["xv"]}

        x, new_cache = jax.lax.scan(body, x, (stacked, layer_cache))
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x)
        new_cache["len"] = pos + 1
        return logits, new_cache
