"""Griffin / RecurrentGemma hybrid LM: RG-LRU + local attention, 1:2.

Layer pattern repeats (recurrent, recurrent, local-attention); every
layer is followed by a SwiGLU MLP.  Super-blocks of 3 layers are scanned
(n_layers // 3 groups); remainder layers (38 % 3 = 2 for the 9B config)
run unrolled with their own parameters.

Sub-quadratic by construction: RG-LRU is a parallel prefix (O(T)) and the
attention layers see only a ``local_window`` slice — this arch runs the
long_500k shape.  Decode caches: per-rec-layer LRU state (B, W) + conv
tail, per-attn-layer a *windowed* KV ring of ``local_window`` entries.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import rglru
from repro.models.shardctx import constrain

Params = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class GriffinLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_super = cfg.n_layers // 3
        self.n_rest = cfg.n_layers % 3  # trailing recurrent layers

    # ------------------------------------------------------------- params
    def init(self, key) -> Params:
        cfg = self.cfg
        pd = _dtype(cfg.param_dtype)
        ks = jax.random.split(key, 6)
        emb, emb_s = L.init_embed(ks[0], cfg.vocab_size, cfg.d_model, pd)
        # two rec layers per super-block, stacked (n_super, 2, ...)
        rec, rec_s = rglru.init_rglru_block(ks[1], cfg, self.n_super * 2, pd)
        rec = jax.tree.map(lambda a: a.reshape(self.n_super, 2, *a.shape[1:]), rec)
        rec_s = {k: ("stack", "stack") + tuple(v[1:]) for k, v in rec_s.items()}
        att, att_s = attn.init_attention(ks[2], cfg, self.n_super, pd)
        mlp, mlp_s = L.init_mlp(ks[3], cfg.n_layers, cfg.d_model, cfg.d_ff, pd)
        mlp = jax.tree.map(
            lambda a: a[: self.n_super * 3].reshape(self.n_super, 3, *a.shape[1:]),
            mlp,
        )
        mlp_s = {k: ("stack", "stack") + tuple(v[1:]) for k, v in mlp_s.items()}
        params: Params = {
            "embed": emb,
            "rec": rec,
            "attn": att,
            "mlp": mlp,
            "ln_t": jnp.zeros((self.n_super, 3, cfg.d_model), pd),  # temporal norms
            "ln_c": jnp.zeros((self.n_super, 3, cfg.d_model), pd),  # channel norms
            "ln_f": jnp.zeros((cfg.d_model,), pd),
        }
        specs: Dict = {
            "embed": emb_s,
            "rec": rec_s,
            "attn": att_s,
            "mlp": mlp_s,
            "ln_t": ("stack", None, None),
            "ln_c": ("stack", None, None),
            "ln_f": (None,),
        }
        if self.n_rest:
            rest, rest_s = rglru.init_rglru_block(ks[4], cfg, self.n_rest, pd)
            rmlp, rmlp_s = L.init_mlp(ks[5], self.n_rest, cfg.d_model, cfg.d_ff, pd)
            params["rest_rec"] = rest
            params["rest_mlp"] = rmlp
            params["rest_ln_t"] = jnp.zeros((self.n_rest, cfg.d_model), pd)
            params["rest_ln_c"] = jnp.zeros((self.n_rest, cfg.d_model), pd)
            specs["rest_rec"] = rest_s
            specs["rest_mlp"] = rmlp_s
            specs["rest_ln_t"] = ("stack", None)
            specs["rest_ln_c"] = ("stack", None)
        self._specs = specs
        return params

    def param_specs(self) -> Dict:
        if not hasattr(self, "_specs"):
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._specs

    def _maybe_remat(self, fn):
        if self.cfg.remat:
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return fn

    # ------------------------------------------------------------ forward
    def _rec_layer(self, pl_rec, ln_t, ln_c, pl_mlp, x):
        cfg = self.cfg
        h = L.rmsnorm(x, ln_t, cfg.norm_eps)
        x = x + rglru.rglru_block(pl_rec, h, cfg)
        h = L.rmsnorm(x, ln_c, cfg.norm_eps)
        return x + L.swiglu_mlp(pl_mlp, h)

    def _attn_layer(self, pl_attn, ln_t, ln_c, pl_mlp, x, positions):
        cfg = self.cfg
        h = L.rmsnorm(x, ln_t, cfg.norm_eps)
        q, k, v = attn.qkv_project(pl_attn, h, cfg, positions)
        o = attn.flash_attention(q, k, v, causal=True, window=cfg.local_window)
        o = jnp.einsum("bshk,hkd->bsd", o, pl_attn["wo"].astype(x.dtype))
        x = x + o
        h = L.rmsnorm(x, ln_c, cfg.norm_eps)
        return x + L.swiglu_mlp(pl_mlp, h)

    def forward(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        stacked = {
            "rec": params["rec"], "attn": params["attn"], "mlp": params["mlp"],
            "ln_t": params["ln_t"], "ln_c": params["ln_c"],
        }

        def super_block(x, pl):
            for j in (0, 1):  # two recurrent layers
                x = self._rec_layer(
                    jax.tree.map(lambda a: a[j], pl["rec"]),
                    pl["ln_t"][j], pl["ln_c"][j],
                    jax.tree.map(lambda a: a[j], pl["mlp"]),
                    x,
                )
            x = self._attn_layer(
                pl["attn"], pl["ln_t"][2], pl["ln_c"][2],
                jax.tree.map(lambda a: a[2], pl["mlp"]),
                x, positions,
            )
            return constrain(x, ("batch", None, None))

        fn = lambda x, pl: (self._maybe_remat(super_block)(x, pl), None)  # noqa: E731
        x, _ = jax.lax.scan(fn, x, stacked)

        for i in range(self.n_rest):
            x = self._rec_layer(
                jax.tree.map(lambda a: a[i], params["rest_rec"]),
                params["rest_ln_t"][i], params["rest_ln_c"][i],
                jax.tree.map(lambda a: a[i], params["rest_mlp"]),
                x,
            )
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.unembed(params["embed"], x)

    def loss_fn(self, params: Params, batch: Dict) -> jnp.ndarray:
        logits = self.forward(params, batch["tokens"])
        return L.softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------ serving
    def cache_specs(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        w = cfg.rglru_width or cfg.d_model
        hd = cfg.resolved_head_dim
        win = min(cfg.local_window, max_len)
        spec = {
            "h": jax.ShapeDtypeStruct((self.n_super, 2, batch, w), jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (self.n_super, 2, batch, cfg.conv_kernel - 1, w), cd
            ),
            "k": jax.ShapeDtypeStruct(
                (self.n_super, batch, win, cfg.n_kv_heads, hd), cd
            ),
            "v": jax.ShapeDtypeStruct(
                (self.n_super, batch, win, cfg.n_kv_heads, hd), cd
            ),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
        if self.n_rest:
            spec["rest_h"] = jax.ShapeDtypeStruct(
                (self.n_rest, batch, w), jnp.float32
            )
            spec["rest_conv"] = jax.ShapeDtypeStruct(
                (self.n_rest, batch, cfg.conv_kernel - 1, w), cd
            )
        return spec

    def cache_logical_specs(self) -> Dict:
        spec = {
            "h": ("stack", None, "batch", "mlp"),
            "conv": ("stack", None, "batch", None, "mlp"),
            "k": ("stack", "batch", "seq", "kv_heads", None),
            "v": ("stack", "batch", "seq", "kv_heads", None),
            "len": (),
        }
        if self.n_rest:
            spec["rest_h"] = ("stack", "batch", "mlp")
            spec["rest_conv"] = ("stack", "batch", None, "mlp")
        return spec

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def decode_step(
        self, params: Params, tokens: jnp.ndarray, cache: Dict
    ) -> Tuple[jnp.ndarray, Dict]:
        """One token; LRU states update in O(1), attention KV is a ring
        buffer of local_window entries (position pos % window)."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b = tokens.shape[0]
        pos = cache["len"]
        win = cache["k"].shape[2]
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(pos[None], (b, 1))

        stacked = {
            "rec": params["rec"], "attn": params["attn"], "mlp": params["mlp"],
            "ln_t": params["ln_t"], "ln_c": params["ln_c"],
        }
        layer_cache = {
            "h": cache["h"], "conv": cache["conv"],
            "k": cache["k"], "v": cache["v"],
        }

        def body(x, inp):
            pl, lc = inp
            new_lc = dict(lc)
            new_h, new_conv = [], []
            for j in (0, 1):
                h = L.rmsnorm(x, pl["ln_t"][j], cfg.norm_eps)
                state = {"h": lc["h"][j], "conv": lc["conv"][j]}
                out, ns = rglru.rglru_decode_step(
                    jax.tree.map(lambda a: a[j], pl["rec"]), h, state, cfg
                )
                x = x + out
                h = L.rmsnorm(x, pl["ln_c"][j], cfg.norm_eps)
                x = x + L.swiglu_mlp(jax.tree.map(lambda a: a[j], pl["mlp"]), h)
                new_h.append(ns["h"])
                new_conv.append(ns["conv"])
            # local attention layer with ring-buffer cache
            h = L.rmsnorm(x, pl["ln_t"][2], cfg.norm_eps)
            q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
            slot = jnp.mod(pos, win)
            k_c = jax.lax.dynamic_update_slice(
                lc["k"], k.astype(lc["k"].dtype), (0, slot, 0, 0)
            )
            v_c = jax.lax.dynamic_update_slice(
                lc["v"], v.astype(lc["v"].dtype), (0, slot, 0, 0)
            )
            # ring buffer holds the last min(pos+1, win) tokens — all valid
            o = attn.decode_attention(
                q, k_c, v_c, jnp.minimum(pos + 1, win), window=0
            )
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
            x = x + o
            h = L.rmsnorm(x, pl["ln_c"][2], cfg.norm_eps)
            x = x + L.swiglu_mlp(jax.tree.map(lambda a: a[2], pl["mlp"]), h)
            new_lc["h"] = jnp.stack(new_h)
            new_lc["conv"] = jnp.stack(new_conv)
            new_lc["k"] = k_c
            new_lc["v"] = v_c
            return x, new_lc

        x, new_cache = jax.lax.scan(body, x, (stacked, layer_cache))

        rest_cache = {}
        if self.n_rest:
            rh, rc = [], []
            for i in range(self.n_rest):
                h = L.rmsnorm(x, params["rest_ln_t"][i], cfg.norm_eps)
                state = {"h": cache["rest_h"][i], "conv": cache["rest_conv"][i]}
                out, ns = rglru.rglru_decode_step(
                    jax.tree.map(lambda a: a[i], params["rest_rec"]), h, state, cfg
                )
                x = x + out
                h = L.rmsnorm(x, params["rest_ln_c"][i], cfg.norm_eps)
                x = x + L.swiglu_mlp(
                    jax.tree.map(lambda a: a[i], params["rest_mlp"]), h
                )
                rh.append(ns["h"])
                rc.append(ns["conv"])
            rest_cache = {"rest_h": jnp.stack(rh), "rest_conv": jnp.stack(rc)}

        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x)
        out_cache = {**new_cache, **rest_cache, "len": pos + 1}
        return logits, out_cache

    def prefill(self, params: Params, tokens: jnp.ndarray) -> Tuple:
        """Prefill = full forward + state extraction via per-token decode
        would be O(T); we run the parallel forward for logits and build
        attention ring caches from the last `window` tokens, LRU states via
        a short scan over the final conv window (exact: LRU state needs the
        full history, so we fold the parallel prefix's final element)."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        win = min(cfg.local_window, s)
        stacked = {
            "rec": params["rec"], "attn": params["attn"], "mlp": params["mlp"],
            "ln_t": params["ln_t"], "ln_c": params["ln_c"],
        }

        def super_block(x, pl):
            caches = {}
            new_h, new_conv = [], []
            for j in (0, 1):
                h = L.rmsnorm(x, pl["ln_t"][j], cfg.norm_eps)
                pl_rec = jax.tree.map(lambda a: a[j], pl["rec"])
                gate = jax.nn.gelu(
                    jnp.einsum("btd,dw->btw", h, pl_rec["w_gate_branch"].astype(h.dtype))
                )
                u = jnp.einsum("btd,dw->btw", h, pl_rec["w_rec_branch"].astype(h.dtype))
                conv_tail = u[:, -(cfg.conv_kernel - 1):, :]
                kk = cfg.conv_kernel
                pad = jnp.pad(u, ((0, 0), (kk - 1, 0), (0, 0)))
                u = sum(
                    pad[:, i : i + u.shape[1], :]
                    * pl_rec["conv_w"][i][None, None, :].astype(h.dtype)
                    for i in range(kk)
                ) + pl_rec["conv_b"][None, None, :].astype(h.dtype)
                a, gated = rglru._gates(pl_rec, u)
                hh = rglru.rglru_scan(a, gated)
                new_h.append(hh[:, -1])
                new_conv.append(conv_tail)
                out = jnp.einsum(
                    "btw,wd->btd", (hh.astype(h.dtype)) * gate,
                    pl_rec["w_out"].astype(h.dtype),
                )
                x = x + out
                h = L.rmsnorm(x, pl["ln_c"][j], cfg.norm_eps)
                x = x + L.swiglu_mlp(jax.tree.map(lambda a: a[j], pl["mlp"]), h)
            h = L.rmsnorm(x, pl["ln_t"][2], cfg.norm_eps)
            q, k, v = attn.qkv_project(pl["attn"], h, cfg, positions)
            o = attn.flash_attention(q, k, v, causal=True,
                                     window=cfg.local_window,
                                     skip_masked_chunks=True)
            o = jnp.einsum("bshk,hkd->bsd", o, pl["attn"]["wo"].astype(x.dtype))
            x = x + o
            h = L.rmsnorm(x, pl["ln_c"][2], cfg.norm_eps)
            x = x + L.swiglu_mlp(jax.tree.map(lambda a: a[2], pl["mlp"]), h)
            caches["h"] = jnp.stack(new_h)
            caches["conv"] = jnp.stack(new_conv)
            # ring-buffer layout: token at absolute position p lives in slot
            # p % win (decode inserts at pos % win), so roll the tail.
            shift = (s - win) % win
            caches["k"] = jnp.roll(k[:, -win:], shift, axis=1)
            caches["v"] = jnp.roll(v[:, -win:], shift, axis=1)
            return x, caches

        def body(carry, pl):
            return self._maybe_remat(super_block)(carry, pl)

        x, caches = jax.lax.scan(body, x, stacked)

        rest = {}
        if self.n_rest:
            rh, rc = [], []
            for i in range(self.n_rest):
                h = L.rmsnorm(x, params["rest_ln_t"][i], cfg.norm_eps)
                pl_rec = jax.tree.map(lambda a: a[i], params["rest_rec"])
                out = rglru.rglru_block(pl_rec, h, cfg)
                # final LRU state via one extra gated pass (small tensors)
                u = jnp.einsum("btd,dw->btw", h, pl_rec["w_rec_branch"].astype(h.dtype))
                rc.append(u[:, -(cfg.conv_kernel - 1):, :])
                kk = cfg.conv_kernel
                pad = jnp.pad(u, ((0, 0), (kk - 1, 0), (0, 0)))
                uc = sum(
                    pad[:, i2 : i2 + u.shape[1], :]
                    * pl_rec["conv_w"][i2][None, None, :].astype(h.dtype)
                    for i2 in range(kk)
                ) + pl_rec["conv_b"][None, None, :].astype(h.dtype)
                a, gated = rglru._gates(pl_rec, uc)
                rh.append(rglru.rglru_scan(a, gated)[:, -1])
                x = x + out
                h = L.rmsnorm(x, params["rest_ln_c"][i], cfg.norm_eps)
                x = x + L.swiglu_mlp(
                    jax.tree.map(lambda a: a[i], params["rest_mlp"]), h
                )
            rest = {"rest_h": jnp.stack(rh), "rest_conv": jnp.stack(rc)}

        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x[:, -1:])
        caches = {**caches, **rest, "len": jnp.asarray(s, jnp.int32)}
        return logits, caches
