"""Mamba-2 language model (attention-free SSM family).

Uniform stack of [pre-norm -> SSD mixer -> residual] layers (Mamba has no
separate FFN; the mixer's expand factor carries the capacity).  Scanned
over layers; decode carries an O(1) state cache — no KV cache, which is
what makes long_500k (524288-token context) a constant-memory decode.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.shardctx import constrain

Params = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class Mamba2LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        pd = _dtype(cfg.param_dtype)
        k1, k2 = jax.random.split(key)
        emb, emb_s = L.init_embed(k1, cfg.vocab_size, cfg.d_model, pd)
        mixer, mixer_s = ssm.init_ssm(k2, cfg, cfg.n_layers, pd)
        self._specs = {
            "embed": emb_s,
            "mixer": mixer_s,
            "ln": ("stack", None),
            "ln_f": (None,),
        }
        return {
            "embed": emb,
            "mixer": mixer,
            "ln": jnp.zeros((cfg.n_layers, cfg.d_model), pd),
            "ln_f": jnp.zeros((cfg.d_model,), pd),
        }

    def param_specs(self) -> Dict:
        if not hasattr(self, "_specs"):
            jax.eval_shape(self.init, jax.random.PRNGKey(0))
        return self._specs

    def _maybe_remat(self, fn):
        if self.cfg.remat:
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return fn

    def forward(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        x = L.embed_tokens(params["embed"], tokens, cd)
        stacked = {"mixer": params["mixer"], "ln": params["ln"]}

        def layer(x, pl):
            h = L.rmsnorm(x, pl["ln"], cfg.norm_eps)
            x = x + ssm.ssm_block(pl["mixer"], h, cfg)
            return constrain(x, ("batch", None, None))

        fn = lambda x, pl: (self._maybe_remat(layer)(x, pl), None)  # noqa: E731
        x, _ = jax.lax.scan(fn, x, stacked)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return L.unembed(params["embed"], x)

    def loss_fn(self, params: Params, batch: Dict) -> jnp.ndarray:
        logits = self.forward(params, batch["tokens"])
        return L.softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))

    # ------------------------------------------------------------ serving
    def cache_specs(self, batch: int, max_len: int) -> Dict:
        """State cache is O(1) in max_len (the SSM long-context win)."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        d_in, h, n = ssm.ssm_dims(cfg)
        conv_dim = d_in + 2 * n
        return {
            "s": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, h, cfg.ssm_head_dim, n), jnp.float32
            ),
            "conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.conv_kernel - 1, conv_dim), cd
            ),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_logical_specs(self) -> Dict:
        return {
            "s": ("stack", "batch", "heads", None, None),
            "conv": ("stack", "batch", None, "mlp"),
            "len": (),
        }

    def init_cache(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def prefill(self, params: Params, tokens: jnp.ndarray) -> Tuple:
        """Chunked SSD over the prompt, emitting final states per layer."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cd)
        stacked = {"mixer": params["mixer"], "ln": params["ln"]}

        def layer(x, pl):
            h = L.rmsnorm(x, pl["ln"], cfg.norm_eps)
            # run mixer capturing final state: re-derive SSD inputs
            d_in, nh, n = ssm.ssm_dims(cfg)
            proj = jnp.einsum("btd,dk->btk", h, pl["mixer"]["in_proj"].astype(h.dtype))
            z, xbc, dt_raw = ssm._split_proj(proj, cfg)
            conv_tail = xbc[:, -(cfg.conv_kernel - 1):, :]
            xbc = ssm._causal_conv(
                xbc, pl["mixer"]["conv_w"].astype(h.dtype),
                pl["mixer"]["conv_b"].astype(h.dtype),
            )
            xs = xbc[..., :d_in]
            Bm = xbc[..., d_in : d_in + n].astype(jnp.float32)
            Cm = xbc[..., d_in + n :].astype(jnp.float32)
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["mixer"]["dt_bias"])
            a = -jnp.exp(pl["mixer"]["A_log"])[None, None, :] * dt
            xh = xs.reshape(*xs.shape[:2], nh, cfg.ssm_head_dim).astype(jnp.float32)
            y, s_fin = ssm.ssd_chunked(xh * dt[..., None], a, Bm, Cm, cfg.ssm_chunk)
            y = y + pl["mixer"]["D"][None, None, :, None] * xh
            y = y.reshape(*h.shape[:2], d_in).astype(h.dtype)
            y = L.rmsnorm(y * jax.nn.silu(z), pl["mixer"]["out_norm"], cfg.norm_eps)
            out = jnp.einsum("btk,kd->btd", y, pl["mixer"]["out_proj"].astype(h.dtype))
            return x + out, {"s": s_fin, "conv": conv_tail}

        def body(carry, pl):
            return self._maybe_remat(layer)(carry, pl)

        x, caches = jax.lax.scan(body, x, stacked)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x[:, -1:])
        caches["len"] = jnp.asarray(s, jnp.int32)
        return logits, caches

    def decode_step(
        self, params: Params, tokens: jnp.ndarray, cache: Dict
    ) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        x = L.embed_tokens(params["embed"], tokens, cd)
        stacked = {"mixer": params["mixer"], "ln": params["ln"]}
        layer_cache = {k: v for k, v in cache.items() if k != "len"}

        def body(x, inp):
            pl, lc = inp
            h = L.rmsnorm(x, pl["ln"], cfg.norm_eps)
            out, new_state = ssm.ssm_decode_step(pl["mixer"], h, lc, cfg)
            return x + out, new_state

        x, new_cache = jax.lax.scan(body, x, (stacked, layer_cache))
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x)
        new_cache["len"] = cache["len"] + 1
        return logits, new_cache
