"""Pass 4 — durability discipline + chaos-point registry drift.

Publish/journal durability edges follow the fsync-before-rename
pattern: the bytes (and for new files, ideally the directory) must be
fsync'd before the ``os.rename`` / ``os.replace`` that makes them
visible, otherwise a power cut can publish a torn file under the final
name.  Every function performing a rename must therefore contain an
``os.fsync`` call lexically before it (``# fsync-ok: <reason>`` waives
edges whose torn writes self-heal, e.g. revalidated cache files).

Each such durability edge must also be covered by crash-safety tests:
the function must contain a registered ``chaos_point(...)`` call, or a
``# chaos-ok: <reason>`` waiver explaining which layer carries the
crash points instead.

Repo-wide, the pass flags drift between ``chaos.CRASH_POINTS`` and the
actual ``chaos_point("...")`` call sites, in both directions: a
registered point with no live call site is dead coverage; an
unregistered name at a call site can never be armed by the chaos
harness.  The same two-way drift check covers the corruption-injection
registry — ``chaos.CORRUPTION_POINTS`` vs ``chaos_corrupt("...")``
call sites — so the integrity tests' corruption sweep and the data
path can never silently diverge.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile
from repro.testing.chaos import CORRUPTION_POINTS, CRASH_POINTS

PASS_ID = "durability"
FSYNC_WAIVER = "fsync-ok"
CHAOS_WAIVER = "chaos-ok"

RENAMES = ("rename", "replace")


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    parents = {}
    for node in ast.walk(sf.tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and _is_os_call(node, RENAMES):
            findings.extend(_check_rename(sf, node, parents))
    return findings


#: (call-site function name, registry tuple, registry symbol, armed-verb)
_REGISTRIES = (
    ("chaos_point", CRASH_POINTS, "CRASH_POINTS", "armed"),
    ("chaos_corrupt", CORRUPTION_POINTS, "CORRUPTION_POINTS", "injected"),
)


def run_repo(files: List[SourceFile]) -> List[Finding]:
    """Cross-file check: chaos registries vs call-site drift, both ways,
    for the crash-point *and* the corruption-point registry."""
    findings: List[Finding] = []
    sites: Dict[str, Dict[str, Tuple[str, int]]] = {
        fn: {} for fn, _pts, _sym, _verb in _REGISTRIES
    }
    registry_file = None
    for sf in files:
        if sf.path.endswith("testing/chaos.py"):
            registry_file = sf
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = None
            if isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node.func, ast.Attribute):
                name = node.func.attr
            if name not in sites or not node.args:
                continue
            arg = node.args[0]
            if not (isinstance(arg, ast.Constant)
                    and isinstance(arg.value, str)):
                continue
            sites[name].setdefault(arg.value, (sf.path, node.lineno))
    for fn, points, symbol, verb in _REGISTRIES:
        for point, (path, lineno) in sorted(sites[fn].items()):
            if point not in points:
                findings.append(Finding(
                    pass_id=PASS_ID, path=path, line=lineno, symbol=fn,
                    message="%s(%r) is not registered in chaos.%s — it "
                            "can never be %s" % (fn, point, symbol, verb),
                ))
        for point in points:
            if point not in sites[fn]:
                path = (registry_file.path if registry_file
                        else "testing/chaos.py")
                findings.append(Finding(
                    pass_id=PASS_ID, path=path, line=1, symbol=symbol,
                    message="registered point %r has no live %s() call "
                            "site" % (point, fn),
                ))
    return findings


# ------------------------------------------------------------- rename
def _check_rename(sf, call, parents) -> List[Finding]:
    findings: List[Finding] = []
    func = _enclosing_function(call, parents)
    fname = func.name if func else "<module>"
    line = call.lineno

    if not _has_call_before(func, ("fsync",), line):
        reason = _waiver(sf, line, func, FSYNC_WAIVER)
        findings.append(Finding(
            pass_id=PASS_ID, path=sf.path, line=line, symbol=fname,
            message="os.%s without a preceding os.fsync in %s() — a "
                    "crash can publish a torn file" % (
                        call.func.attr, fname),
            waived=bool(reason),
            waive_reason=reason or None,
        ))
        if reason == "":
            findings.append(Finding(
                pass_id=PASS_ID, path=sf.path, line=line, symbol=fname,
                message="fsync-ok waiver has no reason",
            ))

    if not _has_chaos_point(func):
        reason = _waiver(sf, line, func, CHAOS_WAIVER)
        findings.append(Finding(
            pass_id=PASS_ID, path=sf.path, line=line, symbol=fname,
            message="durability edge os.%s in %s() has no registered "
                    "chaos_point call site" % (call.func.attr, fname),
            waived=bool(reason),
            waive_reason=reason or None,
        ))
        if reason == "":
            findings.append(Finding(
                pass_id=PASS_ID, path=sf.path, line=line, symbol=fname,
                message="chaos-ok waiver has no reason",
            ))
    return findings


def _waiver(sf, line, func, key):
    reason = sf.waiver_near(line, key)
    if reason is None and func is not None:
        reason = sf.waiver_near(func.lineno, key)
    return reason


def _is_os_call(call: ast.Call, names: Tuple[str, ...]) -> bool:
    f = call.func
    return (
        isinstance(f, ast.Attribute)
        and f.attr in names
        and isinstance(f.value, ast.Name)
        and f.value.id == "os"
    )


def _has_call_before(func, names: Tuple[str, ...], line: int) -> bool:
    if func is None:
        return False
    for node in ast.walk(func):
        if isinstance(node, ast.Call) and node.lineno <= line:
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in names:
                return True
    return False


def _has_chaos_point(func) -> bool:
    if func is None:
        return False
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            f = node.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            if name == "chaos_point":
                return True
    return False


def _enclosing_function(node, parents):
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None
