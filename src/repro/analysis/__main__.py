"""``python -m repro.analysis`` — run mergelint from the command line.

Exit codes: 0 clean, 1 findings, 2 usage error.

Examples::

    python -m repro.analysis                     # lint the repo (text)
    python -m repro.analysis --format json       # machine-readable
    python -m repro.analysis --show-waived       # include waived findings
    python -m repro.analysis --passes guarded-by,durability
    python -m repro.analysis src/repro/store/tiered.py
    python -m repro.analysis --update-baseline   # bootstrap only
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import baseline as baseline_mod
from repro.analysis import runner
from repro.analysis.findings import render_json, render_text


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="mergelint: repo-specific static analysis for MergePipe",
    )
    ap.add_argument("paths", nargs="*",
                    help="files to lint (default: all of src/repro)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of: %s"
                         % ",".join(runner.ALL_PASSES))
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived findings (text format)")
    ap.add_argument("--list-passes", action="store_true")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: <root>/%s)"
                         % baseline_mod.BASELINE_NAME)
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings to the baseline file "
                         "(bootstrap; entries still need reasons)")
    args = ap.parse_args(argv)

    if args.list_passes:
        for pid in runner.ALL_PASSES:
            print(pid)
        return 0

    passes = None
    if args.passes:
        passes = [p.strip() for p in args.passes.split(",") if p.strip()]
        unknown = [p for p in passes if p not in runner.ALL_PASSES]
        if unknown:
            print("mergelint: unknown pass(es): %s" % ", ".join(unknown),
                  file=sys.stderr)
            return 2

    root = args.root or runner.find_repo_root(os.getcwd())
    baseline_path = args.baseline or os.path.join(
        root, baseline_mod.BASELINE_NAME)

    if args.paths:
        paths = [os.path.abspath(p) for p in args.paths]
        findings = runner.run_paths(paths, root=root, passes=passes)
        findings.extend(baseline_mod.lint_baseline(baseline_path))
        baseline_mod.apply(findings, baseline_mod.load(baseline_path))
    else:
        findings = runner.run_repo(
            root, passes=passes, baseline_path=baseline_path)

    if args.update_baseline:
        n = baseline_mod.write(baseline_path, findings)
        print("mergelint: wrote %d entr%s to %s (add reasons before "
              "committing)" % (n, "y" if n == 1 else "ies", baseline_path))
        return 0

    if args.format == "json":
        print(render_json(findings))
    else:
        print(render_text(findings, show_waived=args.show_waived))
    return 1 if any(not f.waived for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
