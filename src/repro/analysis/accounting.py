"""Pass 2 — IOStats accounting completeness.

Budget enforcement (realized C_expert <= planned <= B) is only sound if
every byte read reaches an :class:`repro.store.iostats.IOStats`
category.  This pass watches the three read primitives named in the
repo's accounting contract — ``read_range``, ``pread`` (incl. the
``os.pread``-based ``_pread`` helpers) and ``get_range`` — and requires
each call site to be *accounted*: either a category flows through the
call (a ``category=...`` argument, a variable named ``category``/
``cat``, or a literal category string), or the enclosing function
itself records the bytes via an ``IOStats.record_*`` / ``_record``
helper call.  Call sites whose bytes are recorded by a caller one layer
up carry ``# unaccounted-ok: <reason>``.

It also validates every literal category string (in watched calls and
in ``record_read``/``record_write``/``record_skip``) against
``iostats.CATEGORIES`` — a typo'd category would silently escape every
``C_*`` aggregate.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile
from repro.store.iostats import CATEGORIES

PASS_ID = "io-accounting"
WAIVER = "unaccounted-ok"

READ_PRIMITIVES = ("read_range", "get_range", "pread", "_pread")
RECORDERS = ("record_read", "record_write", "record_skip")
_RECORD_CALL = re.compile(r"^_?record(_\w+)?$")


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    # enclosing-function index: maps every node to its nearest def
    parents = {}
    for node in ast.walk(sf.tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in READ_PRIMITIVES:
            findings.extend(_check_read_site(sf, node, name, parents))
        if name in RECORDERS:
            findings.extend(_check_category_literal(sf, node, name))
    return findings


def _check_read_site(sf, call, name, parents) -> List[Finding]:
    findings: List[Finding] = []
    func = _enclosing_function(call, parents)
    fname = func.name if func else "<module>"
    # literal categories on the call itself are validated either way
    for arg in list(call.args) + [kw.value for kw in call.keywords]:
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            if arg.value in CATEGORIES:
                continue
            if _looks_like_category(call, arg):
                findings.append(Finding(
                    pass_id=PASS_ID, path=sf.path, line=arg.lineno,
                    symbol=fname,
                    message="unknown IOStats category %r passed to %s()"
                            % (arg.value, name),
                ))
    if _carries_category(call) or _function_records(func):
        return findings
    line = call.lineno
    reason = sf.waiver_near(line, WAIVER)
    if reason is None and func is not None:
        reason = sf.waiver_near(func.lineno, WAIVER)
    findings.append(Finding(
        pass_id=PASS_ID, path=sf.path, line=line, symbol=fname,
        message="%s() call site not accounted: no category flows in and "
                "%s() never records to IOStats" % (name, fname),
        waived=bool(reason),
        waive_reason=reason or None,
    ))
    if reason == "":
        findings.append(Finding(
            pass_id=PASS_ID, path=sf.path, line=line, symbol=fname,
            message="unaccounted-ok waiver has no reason",
        ))
    return findings


def _check_category_literal(sf, call, name) -> List[Finding]:
    args = list(call.args)
    cat = None
    for kw in call.keywords:
        if kw.arg == "category":
            cat = kw.value
    if cat is None and args:
        cat = args[0]
    if isinstance(cat, ast.Constant) and isinstance(cat.value, str):
        if cat.value not in CATEGORIES:
            func_name = _call_name(call) or name
            return [Finding(
                pass_id=PASS_ID, path=sf.path, line=call.lineno,
                symbol=func_name,
                message="unknown IOStats category %r passed to %s()"
                        % (cat.value, name),
            )]
    return []


# ------------------------------------------------------------- helpers
def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _enclosing_function(node, parents):
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None


def _carries_category(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "category":
            return True
    for arg in list(call.args) + [kw.value for kw in call.keywords]:
        if isinstance(arg, ast.Name) and (
            "category" in arg.id or arg.id == "cat"
        ):
            return True
        if isinstance(arg, ast.Attribute) and "category" in arg.attr:
            return True
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str) \
                and arg.value in CATEGORIES:
            return True
    return False


def _function_records(func) -> bool:
    if func is None:
        return False
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name and name not in READ_PRIMITIVES \
                    and _RECORD_CALL.match(name):
                return True
    return False


def _looks_like_category(call: ast.Call, arg) -> bool:
    """Heuristic: a string arg to a read primitive is a category when it
    is the ``category`` keyword or matches a category-ish shape."""
    for kw in call.keywords:
        if kw.arg == "category" and kw.value is arg:
            return True
    return False
