"""mergelint — repo-specific static analysis for MergePipe.

The system's headline claims (transactional materialization,
budget-enforced expert I/O, crash-safe resume) rest on hand-maintained
conventions scattered across ~10 threaded modules: "this dict is guarded
by ``_lock``", "every expert byte lands in an IOStats category", "fsync
before rename", "``SimulatedCrash`` must stay invisible to abort paths".
This package machine-checks those conventions with four AST passes:

* :mod:`repro.analysis.guarded` — ``# guarded-by: <lock>`` field
  discipline (every access under ``with self.<lock>``);
* :mod:`repro.analysis.accounting` — IOStats accounting completeness
  for ``read_range`` / ``pread`` / ``get_range`` call sites;
* :mod:`repro.analysis.exceptions` — exception discipline (no broad
  handler may swallow ``MergeCancelled`` / ``SimulatedCrash`` silently);
* :mod:`repro.analysis.durability` — fsync-before-rename plus
  ``chaos.CRASH_POINTS`` registry/call-site drift.

Run ``python -m repro.analysis`` from the repo root (see
docs/ANALYSIS.md).  The runtime companion is
:mod:`repro.testing.locktrace`, a lock-order tracer used by the test
suite to catch potential deadlocks dynamically.
"""
from repro.analysis.findings import Finding
from repro.analysis.runner import ALL_PASSES, run_paths, run_repo

__all__ = ["Finding", "ALL_PASSES", "run_paths", "run_repo"]
