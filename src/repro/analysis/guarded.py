"""Pass 1 — guarded-by discipline.

A field annotated ``# guarded-by: <lock>`` on its assignment line (by
convention the initial assignment in ``__init__``) may only be read or
written lexically under ``with self.<lock>``.  ``__init__`` itself is
exempt: construction happens-before publication of ``self`` to other
threads.  Closures and nested ``def``s do NOT inherit the enclosing
``with`` — they may run on another thread, so an access inside one
needs its own lock or a waiver.

Waive with ``# unguarded-ok: <reason>`` on the access line, or on the
``def`` line to waive a whole helper whose contract is "caller holds
the lock".
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile

PASS_ID = "guarded-by"
WAIVER = "unguarded-ok"


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_check_class(sf, node))
    return findings


# ---------------------------------------------------------------- class
def _check_class(sf: SourceFile, cls: ast.ClassDef) -> List[Finding]:
    fields = _annotated_fields(sf, cls)
    findings: List[Finding] = []
    for name, (lock, line, dup) in fields.items():
        if dup:
            findings.append(Finding(
                pass_id=PASS_ID, path=sf.path, line=line,
                symbol="%s.%s" % (cls.name, name),
                message="field annotated guarded-by twice with different "
                        "locks (%s vs %s)" % (lock, dup),
            ))
    if not fields:
        return findings
    locks = {lock for lock, _, _ in fields.values()}
    for item in cls.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if item.name == "__init__":
            continue
        method_waiver = sf.waiver_near(item.lineno, WAIVER)
        _visit(sf, cls, item, item, frozenset(), fields, locks,
               method_waiver, findings)
    return findings


def _annotated_fields(
    sf: SourceFile, cls: ast.ClassDef
) -> Dict[str, Tuple[str, int, Optional[str]]]:
    """``field -> (lock, annotation line, conflicting lock or None)``."""
    fields: Dict[str, Tuple[str, int, Optional[str]]] = {}
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            lock = sf.guarded_by(node.lineno)
            if not lock:
                continue
            targets = (
                node.targets if isinstance(node, ast.Assign)
                else [node.target]
            )
            for tgt in targets:
                if _is_self_attr(tgt):
                    name = tgt.attr
                    if name in fields and fields[name][0] != lock:
                        prev = fields[name]
                        fields[name] = (prev[0], prev[1], lock)
                    else:
                        fields.setdefault(name, (lock, node.lineno, None))
    return fields


# --------------------------------------------------------------- visit
def _visit(
    sf: SourceFile,
    cls: ast.ClassDef,
    method: ast.AST,
    node: ast.AST,
    held: frozenset,
    fields: Dict[str, Tuple[str, int, Optional[str]]],
    locks: Set[str],
    method_waiver: Optional[str],
    findings: List[Finding],
) -> None:
    for child in ast.iter_child_nodes(node):
        _dispatch(sf, cls, method, child, held, fields, locks,
                  method_waiver, findings)


def _dispatch(
    sf: SourceFile,
    cls: ast.ClassDef,
    method: ast.AST,
    child: ast.AST,
    held: frozenset,
    fields: Dict[str, Tuple[str, int, Optional[str]]],
    locks: Set[str],
    method_waiver: Optional[str],
    findings: List[Finding],
) -> None:
    if isinstance(child, ast.With):
        child_held = held | _locks_entered(child, locks)
        # the with-items themselves evaluate before the lock is held
        for w in child.items:
            _dispatch(sf, cls, method, w, held, fields, locks,
                      method_waiver, findings)
        for stmt in child.body:
            _dispatch(sf, cls, method, stmt, child_held, fields, locks,
                      method_waiver, findings)
        return
    if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
        # closures may run on another thread: locks do not carry over
        nested_waiver = method_waiver
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            nested_waiver = (
                sf.waiver_near(child.lineno, WAIVER) or method_waiver
            )
        _visit(sf, cls, method, child, frozenset(), fields, locks,
               nested_waiver, findings)
        return
    if isinstance(child, ast.Attribute) and _is_self_attr(child):
        name = child.attr
        if name in fields:
            lock, ann_line, _ = fields[name]
            # the annotating assignment IS the construction point
            # (usually __init__ or an _init helper): exempt it
            if lock not in held and child.lineno != ann_line:
                _report(sf, cls, method, child, lock, method_waiver,
                        findings)
    _visit(sf, cls, method, child, held, fields, locks,
           method_waiver, findings)


def _report(sf, cls, method, node, lock, method_waiver, findings) -> None:
    line = node.lineno
    reason = sf.waiver_near(line, WAIVER)
    if reason is None:
        reason = method_waiver
    mname = getattr(method, "name", "<lambda>")
    kind = "write" if isinstance(node.ctx, (ast.Store, ast.Del)) else "read"
    findings.append(Finding(
        pass_id=PASS_ID, path=sf.path, line=line,
        symbol="%s.%s" % (cls.name, mname),
        message="%s of self.%s outside `with self.%s`" % (
            kind, node.attr, lock),
        waived=bool(reason),
        waive_reason=reason or None,
    ))
    if reason == "":
        findings.append(Finding(
            pass_id=PASS_ID, path=sf.path, line=line,
            symbol="%s.%s" % (cls.name, mname),
            message="unguarded-ok waiver has no reason",
        ))


def _locks_entered(node: ast.With, locks: Set[str]) -> frozenset:
    out = set()
    for item in node.items:
        expr = item.context_expr
        if _is_self_attr(expr) and expr.attr in locks:
            out.add(expr.attr)
    return frozenset(out)


def _is_self_attr(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    )
