"""Finding model + text/JSON reporters for mergelint."""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Finding:
    """One rule violation.

    ``fingerprint`` deliberately excludes the line number so that a
    baseline entry survives unrelated edits to the same file; it hashes
    the pass, the file, the symbol (usually ``Class.method`` or
    ``Class.field``) and the message.
    """

    pass_id: str          # e.g. "guarded-by"
    path: str             # repo-relative posix path
    line: int             # 1-based
    symbol: str           # Class.method / Class.field / module-level name
    message: str
    waived: bool = False
    waive_reason: Optional[str] = None
    extra: Dict = field(default_factory=dict)

    @property
    def fingerprint(self) -> str:
        raw = "|".join((self.pass_id, self.path, self.symbol, self.message))
        return hashlib.sha256(raw.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict:
        return {
            "pass": self.pass_id,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "message": self.message,
            "fingerprint": self.fingerprint,
            "waived": self.waived,
            "waive_reason": self.waive_reason,
        }

    def render(self) -> str:
        tag = " (waived: %s)" % self.waive_reason if self.waived else ""
        return "%s:%d: [%s] %s — %s%s" % (
            self.path, self.line, self.pass_id, self.symbol, self.message, tag,
        )


def render_text(findings: List[Finding], show_waived: bool = False) -> str:
    lines = []
    active = [f for f in findings if not f.waived]
    for f in sorted(active, key=lambda f: (f.path, f.line)):
        lines.append(f.render())
    if show_waived:
        for f in sorted((f for f in findings if f.waived),
                        key=lambda f: (f.path, f.line)):
            lines.append(f.render())
    n_waived = sum(1 for f in findings if f.waived)
    lines.append(
        "mergelint: %d finding(s), %d waived" % (len(active), n_waived)
    )
    return "\n".join(lines)


def render_json(findings: List[Finding]) -> str:
    doc = {
        "tool": "mergelint",
        "findings": [f.to_dict() for f in findings if not f.waived],
        "waived": [f.to_dict() for f in findings if f.waived],
    }
    return json.dumps(doc, indent=2, sort_keys=True)
