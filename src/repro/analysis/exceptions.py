"""Pass 3 — exception discipline.

Two invariants from the crash-safety design (docs/RECOVERY.md):

* ``MergeCancelled`` (a ``RuntimeError``) must propagate to the layer
  that settles the job handle — so an ``except Exception`` on a path it
  crosses must either re-raise or be waived with a reason explaining
  where cancellation is handled.
* ``SimulatedCrash`` derives from ``BaseException`` precisely so that
  abort paths (``except Exception: txn.abort()``) cannot see it.  A
  bare ``except:`` or ``except BaseException:`` that does not re-raise
  would swallow a simulated crash and turn a resumable death into a
  silent success — flagged unless it re-raises or is waived.

Waive with ``# broad-except-ok: <reason>`` on the ``except`` line.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile

PASS_ID = "except-discipline"
WAIVER = "broad-except-ok"

BROAD = ("Exception",)
CRASH_VISIBLE = ("BaseException",)   # can see SimulatedCrash


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    parents = {}
    for node in ast.walk(sf.tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ExceptHandler):
            findings.extend(_check_handler(sf, node, parents))
    return findings


def _check_handler(sf, handler: ast.ExceptHandler, parents) -> List[Finding]:
    kinds = _caught_names(handler.type)
    if kinds is None:
        label = "bare except:"
        severity = "swallows SimulatedCrash"
    elif any(k in CRASH_VISIBLE for k in kinds):
        label = "except BaseException"
        severity = "swallows SimulatedCrash"
    elif any(k in BROAD for k in kinds):
        label = "except Exception"
        severity = "swallows MergeCancelled"
    else:
        return []
    if _reraises(handler):
        return []
    func = _enclosing_function(handler, parents)
    fname = func.name if func else "<module>"
    reason = sf.waiver_near(handler.lineno, WAIVER)
    findings = [Finding(
        pass_id=PASS_ID, path=sf.path, line=handler.lineno, symbol=fname,
        message="%s without re-raise %s" % (label, severity),
        waived=bool(reason),
        waive_reason=reason or None,
    )]
    if reason == "":
        findings.append(Finding(
            pass_id=PASS_ID, path=sf.path, line=handler.lineno,
            symbol=fname, message="broad-except-ok waiver has no reason",
        ))
    return findings


def _caught_names(node) -> Optional[List[str]]:
    """Exception class names caught; ``None`` for a bare ``except:``."""
    if node is None:
        return None
    names: List[str] = []
    stack = [node]
    while stack:
        cur = stack.pop()
        if isinstance(cur, ast.Tuple):
            stack.extend(cur.elts)
        elif isinstance(cur, ast.Name):
            names.append(cur.id)
        elif isinstance(cur, ast.Attribute):
            names.append(cur.attr)
    return names


def _reraises(handler: ast.ExceptHandler) -> bool:
    """True if the handler body contains a ``raise`` on every relevant
    path — approximated as: any ``raise`` statement outside nested
    function definitions."""
    stack = list(handler.body)
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))
    return False


def _enclosing_function(node, parents):
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None
