"""File discovery + pass orchestration for mergelint."""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence

from repro.analysis import accounting, durability, exceptions, guarded
from repro.analysis import baseline as baseline_mod
from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile

# pass-id -> per-file entry point
ALL_PASSES = {
    guarded.PASS_ID: guarded.run,
    accounting.PASS_ID: accounting.run,
    exceptions.PASS_ID: exceptions.run,
    durability.PASS_ID: durability.run,
}
# repo-wide passes run once over the whole file set
REPO_PASSES = {durability.PASS_ID + "-drift": durability.run_repo}


def discover(root: str) -> List[str]:
    """All lintable .py files: ``src/repro`` relative to ``root``."""
    src = os.path.join(root, "src", "repro")
    out: List[str] = []
    for dirpath, dirnames, filenames in os.walk(src):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


def parse_files(paths: Sequence[str], root: str) -> List[SourceFile]:
    files: List[SourceFile] = []
    for path in paths:
        with open(path) as f:
            text = f.read()
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        files.append(SourceFile.parse(rel, text))
    return files


def run_paths(
    paths: Sequence[str],
    root: str = ".",
    passes: Optional[Sequence[str]] = None,
    with_repo_passes: bool = True,
) -> List[Finding]:
    files = parse_files(paths, root)
    selected = passes or list(ALL_PASSES)
    findings: List[Finding] = []
    for sf in files:
        for pid in selected:
            findings.extend(ALL_PASSES[pid](sf))
    if with_repo_passes and (passes is None or durability.PASS_ID in passes):
        for run_repo in REPO_PASSES.values():
            findings.extend(run_repo(files))
    return findings


def run_repo(
    root: str,
    passes: Optional[Sequence[str]] = None,
    baseline_path: Optional[str] = None,
) -> List[Finding]:
    """Lint the whole repo and apply the checked-in baseline."""
    findings = run_paths(discover(root), root=root, passes=passes)
    if baseline_path is None:
        baseline_path = os.path.join(root, baseline_mod.BASELINE_NAME)
    findings.extend(baseline_mod.lint_baseline(baseline_path))
    baseline_mod.apply(findings, baseline_mod.load(baseline_path))
    return findings


def find_repo_root(start: str = ".") -> str:
    """Walk up from ``start`` to the directory containing src/repro."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            raise SystemExit(
                "mergelint: cannot find repo root (src/repro) from %s"
                % os.path.abspath(start)
            )
        cur = parent
