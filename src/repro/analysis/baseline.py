"""Checked-in baseline for mergelint.

The baseline exists so that *pre-existing, reasoned* waivers are
explicit and reviewable — it is not an amnesty mechanism.  Policy: fix
real violations; waive deliberate ones inline (the inline waiver
carries its reason next to the code); baseline only findings that
cannot carry an inline comment (e.g. generated files).  Every entry
must have a non-empty ``reason``.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.analysis.findings import Finding

BASELINE_NAME = "mergelint.baseline.json"


def load(path: str) -> Dict[str, str]:
    """``fingerprint -> reason``; missing file means empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        doc = json.load(f)
    out: Dict[str, str] = {}
    for entry in doc.get("entries", []):
        out[entry["fingerprint"]] = entry.get("reason", "")
    return out


def apply(findings: List[Finding], baseline: Dict[str, str]) -> List[Finding]:
    """Mark findings present in the baseline as waived (in place)."""
    for f in findings:
        if f.waived:
            continue
        reason = baseline.get(f.fingerprint)
        if reason:
            f.waived = True
            f.waive_reason = "baseline: " + reason
    return findings


def write(path: str, findings: List[Finding]) -> int:
    """Write all currently-active findings as baseline entries.

    Intended for bootstrapping only; entries get a placeholder reason
    that the lint itself will reject until a human replaces it.
    """
    entries = []
    for f in sorted((f for f in findings if not f.waived),
                    key=lambda f: (f.path, f.line)):
        entries.append({
            "fingerprint": f.fingerprint,
            "pass": f.pass_id,
            "path": f.path,
            "symbol": f.symbol,
            "message": f.message,
            "reason": "",
        })
    doc = {"version": 1, "entries": entries}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return len(entries)


def lint_baseline(path: str) -> List[Finding]:
    """The baseline file itself is linted: entries need real reasons."""
    findings: List[Finding] = []
    if not os.path.exists(path):
        return findings
    with open(path) as f:
        doc = json.load(f)
    for i, entry in enumerate(doc.get("entries", [])):
        if not entry.get("reason"):
            findings.append(Finding(
                pass_id="baseline", path=os.path.basename(path), line=i + 1,
                symbol=entry.get("fingerprint", "?"),
                message="baseline entry for %s (%s) has no reason" % (
                    entry.get("path", "?"), entry.get("message", "?")),
            ))
    return findings
