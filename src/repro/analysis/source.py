"""Parsed source model shared by all mergelint passes.

Annotation / waiver grammar (all live in ordinary ``#`` comments):

``# guarded-by: <lock>``
    On a ``self.<field> = ...`` line: every other access of that field
    in the class must occur lexically under ``with self.<lock>``.

``# unguarded-ok: <reason>``
    Waives a guarded-by finding on that line (deliberate lock-free
    access; the reason must say why it is safe).

``# unaccounted-ok: <reason>``
    Waives an IOStats accounting finding on a read call site whose
    bytes are recorded by a caller at a different layer.

``# broad-except-ok: <reason>``
    Waives an exception-discipline finding on an ``except`` line; the
    reason must explain why ``MergeCancelled`` / ``SimulatedCrash``
    cannot be swallowed there.

``# fsync-ok: <reason>``
    Waives a fsync-before-rename finding (e.g. a cache file whose torn
    write self-heals).

``# chaos-ok: <reason>``
    Waives the "durability edge has no registered chaos point" check
    (e.g. the crash points bracket the call one layer up).

A waiver without a reason is itself reported — the reason is the
documentation the next reader gets.
"""
from __future__ import annotations

import ast
import io
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

WAIVER_KEYS = (
    "unguarded-ok",
    "unaccounted-ok",
    "broad-except-ok",
    "fsync-ok",
    "chaos-ok",
)


@dataclass
class SourceFile:
    path: str                    # repo-relative posix path
    text: str
    tree: ast.Module
    comments: Dict[int, str] = field(default_factory=dict)  # line -> text
    # line -> {waiver_key: reason}; "" reason means malformed waiver
    waivers: Dict[int, Dict[str, str]] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, text: str) -> "SourceFile":
        tree = ast.parse(text, filename=path)
        comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    comments[tok.start[0]] = tok.string
        except tokenize.TokenError:
            pass
        waivers: Dict[int, Dict[str, str]] = {}
        for line, comment in comments.items():
            for key, reason in _parse_directives(comment):
                waivers.setdefault(line, {})[key] = reason
        return cls(path=path, text=text, tree=tree,
                   comments=comments, waivers=waivers)

    # ------------------------------------------------------------------
    def waiver(self, line: int, key: str) -> Optional[str]:
        """Reason string if ``line`` carries ``# <key>: reason``.

        Returns ``""`` for a malformed (reason-less) waiver and ``None``
        when no waiver of that kind is present.
        """
        entry = self.waivers.get(line)
        if entry is None:
            return None
        return entry.get(key)

    def waiver_near(self, line: int, key: str) -> Optional[str]:
        """Like :meth:`waiver`, but also accepts the waiver on a block of
        comment-only lines immediately above ``line`` (the usual style
        when the code line is already long)."""
        reason = self.waiver(line, key)
        if reason is not None:
            return reason
        lines = self.text.splitlines()
        cur = line - 1
        while cur >= 1 and cur <= len(lines) \
                and lines[cur - 1].lstrip().startswith("#"):
            reason = self.waiver(cur, key)
            if reason is not None:
                return reason
            cur -= 1
        return None

    def guarded_by(self, line: int) -> Optional[str]:
        """Lock name if ``line`` carries ``# guarded-by: <lock>``."""
        comment = self.comments.get(line)
        if not comment:
            return None
        for key, value in _parse_directives(comment, keys=("guarded-by",)):
            return value or None
        return None


def _parse_directives(
    comment: str, keys: Tuple[str, ...] = WAIVER_KEYS
) -> List[Tuple[str, str]]:
    """Extract ``key: value`` directives from one comment string."""
    out: List[Tuple[str, str]] = []
    body = comment.lstrip("#").strip()
    for key in keys:
        marker = key + ":"
        idx = body.find(marker)
        if idx < 0:
            # bare "# unguarded-ok" with no colon: malformed, empty reason
            if body == key or body.startswith(key + " "):
                out.append((key, ""))
            continue
        # only accept the directive at a comment-word boundary
        if idx > 0 and body[idx - 1] not in " ;,(":
            continue
        reason = body[idx + len(marker):].strip()
        # a follow-on directive ends the reason
        for other in keys:
            cut = reason.find(other + ":")
            if cut > 0:
                reason = reason[:cut].rstrip(" ;,")
        out.append((key, reason))
    return out
