"""Shared workspace surface for :class:`~repro.api.session.Session` and
:class:`~repro.api.service.MergeService`.

Both own the same substrate (``self.snapshots`` / ``self.catalog`` /
``self.block_size``); this mixin keeps their ingestion, audit, and data
accessors one implementation instead of two drifting copies.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.core.lineage import explain as _explain
from repro.core.lineage import lineage_chain, merge_graph, verify_snapshot
from repro.core.sketch import analyze_model
from repro.store.tensorstore import load_model_arrays


class WorkspaceOps:
    """Ingestion / audit / data accessors over one workspace substrate."""

    # ------------------------------------------------------------ ingestion
    def register_model(
        self,
        model_id: str,
        arrays: Mapping[str, np.ndarray],
        kind: str = "full",
        scale: float = 1.0,
        analyze: bool = False,
        base_id: Optional[str] = None,
    ) -> str:
        meta: Dict[str, Any] = {"kind": kind}
        if kind == "adapter":
            meta["scale"] = scale
        self.snapshots.models.write_model(model_id, arrays, meta=meta)
        if analyze:
            self.analyze(model_id, base_id=base_id)
        return model_id

    def analyze(
        self, model_id: str, base_id: Optional[str] = None, force: bool = False
    ) -> Dict:
        return analyze_model(
            self.catalog,
            self.snapshots.models,
            model_id,
            self.block_size,
            base_id=base_id,
            force=force,
        )

    def ensure_analyzed(self, base_id: str, expert_ids: Sequence[str]) -> None:
        self.analyze(base_id)
        for e in expert_ids:
            self.analyze(e, base_id=base_id)

    # ------------------------------------------------- remote-backed models
    def register_remote_model(
        self,
        model_id: str,
        remote_root: str,
        profile: Optional[Dict[str, Any]] = None,
        disk_cache: bool = True,
        analyze: bool = False,
        base_id: Optional[str] = None,
    ) -> str:
        """Register a model already published in a remote object store
        (``<remote_root>/<model_id>/...``).  Reads are served through the
        tier hierarchy RAM -> local disk cache -> remote; ``profile``
        sets the emulated endpoint's latency/bandwidth/fault shape (see
        :class:`repro.store.remote.RemoteProfile`)."""
        self.snapshots.models.register_remote(
            model_id, remote_root, profile=profile, disk_cache=disk_cache
        )
        if analyze:
            self.analyze(model_id, base_id=base_id)
        return model_id

    def publish_model_remote(
        self,
        model_id: str,
        remote_root: str,
        profile: Optional[Dict[str, Any]] = None,
        keep_local: bool = False,
        disk_cache: bool = True,
    ) -> str:
        """Upload a locally registered model to a remote object store and
        (unless ``keep_local``) replace the local bytes with a remote
        stub, so later reads exercise the tiered path."""
        return self.snapshots.models.publish_remote(
            model_id,
            remote_root,
            profile=profile,
            keep_local=keep_local,
            disk_cache=disk_cache,
        )

    def disk_cache_stats(self) -> Dict[str, int]:
        """Usage/hit counters of the shared local-disk extent cache."""
        return self.snapshots.disk_cache.cache_stats()

    def evict_disk_cache(self, target_bytes: int = 0) -> int:
        """Shrink the shared disk cache to ``target_bytes`` (0 = clear).
        Returns bytes freed."""
        return self.snapshots.disk_cache.evict(target_bytes)

    # ---------------------------------------------------------------- audit
    def explain(self, sid: str) -> Dict:
        return _explain(self.catalog, self.snapshots, sid)

    def merge_graph(self, sid: str) -> Dict:
        return merge_graph(self.catalog, sid)

    def lineage(self, sid: str):
        return lineage_chain(self.catalog, sid)

    def verify(self, sid: str) -> bool:
        return verify_snapshot(self.snapshots, sid)

    def fsck(self, repair: bool = False, rate_mbps: float = 0.0):
        """mergefsck: scrub every store of this workspace (models,
        remote stubs, snapshots, packed layouts, disk cache, journals)
        against the block-integrity contract.  Returns a
        :class:`repro.store.fsck.FsckReport`; see that module for what
        each pass checks and what ``repair`` may mutate."""
        from repro.store.fsck import fsck as _fsck

        return _fsck(self.snapshots, repair=repair, rate_mbps=rate_mbps)

    # ----------------------------------------------------------------- data
    def load(self, model_id: str) -> Dict[str, np.ndarray]:
        return load_model_arrays(self.snapshots.models, model_id)

    def list_snapshots(self) -> List[str]:
        return self.snapshots.list_snapshots()
