"""repro.api — the declarative MergePipe API (v2).

Public surface:

    BudgetSpec    typed expert-read budgets ("30%", "2GiB", bytes, ...)
    OperatorSpec  schema-validated operator + θ
    MergeSpec     composable merge-graph node (inputs may be MergeSpecs)
    MergeService  asynchronous, continuously-scheduling job service:
                  submit(spec, tenant=..., priority=..., deadline=...)
                  with admission control, weighted-fair budget
                  arbitration, and cancellation (docs/SERVICE.md)
    Session       workspace entry point; submit()/run_all() batches are
                  a compatibility wrapper over an embedded MergeService
    JobHandle     future-style handle: wait()/status/progress()/cancel()
    JobState / JobCancelled / AdmissionRejected / DeadlineExceeded
                  job lifecycle vocabulary
    load_spec_file  parse a YAML/JSON spec document into MergeSpecs

The legacy one-shot facade (:class:`repro.core.api.MergePipe`) delegates
here and remains supported; new code should target this layer.
"""
from __future__ import annotations

import json
from typing import List

from repro.api.budget import BudgetSpec
from repro.api.jobs import (
    AdmissionRejected,
    DeadlineExceeded,
    JobCancelled,
    JobHandle,
    JobState,
)
from repro.api.service import BudgetArbiter, MergeService
from repro.api.session import Session
from repro.api.spec import MergeSpec, OperatorSpec

__all__ = [
    "BudgetSpec",
    "OperatorSpec",
    "MergeSpec",
    "MergeService",
    "BudgetArbiter",
    "Session",
    "JobHandle",
    "JobState",
    "JobCancelled",
    "AdmissionRejected",
    "DeadlineExceeded",
    "load_spec_file",
]


def load_spec_file(path: str) -> List[MergeSpec]:
    """Load one or many MergeSpecs from a YAML or JSON document.

    Accepted shapes: a single spec mapping, a list of spec mappings, or
    ``{"jobs": [...]}``.  YAML needs PyYAML; JSON always works.
    """
    with open(path, "r", encoding="utf-8") as f:
        raw = f.read()
    if path.endswith((".yaml", ".yml")):
        try:
            import yaml
        except ImportError as e:  # pragma: no cover
            raise RuntimeError(
                f"PyYAML is required to load {path}; install pyyaml or use JSON"
            ) from e
        doc = yaml.safe_load(raw)
    else:
        doc = json.loads(raw)
    if isinstance(doc, dict) and "jobs" in doc:
        doc = doc["jobs"]
    if isinstance(doc, dict):
        doc = [doc]
    if not isinstance(doc, list):
        raise ValueError(f"spec document {path} must be a mapping or list")
    return [MergeSpec.from_dict(d) for d in doc]
