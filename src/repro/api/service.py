"""MergeService — the asynchronous, continuously-scheduling job API.

The v2 :class:`~repro.api.session.Session` arbitrates the expert-read
budget only inside a single blocking ``run_all()`` barrier: jobs that
arrive after planning starts wait for the whole batch, and nothing
bounds or shares budget across concurrent callers.  ``MergeService``
replaces that barrier with a long-lived scheduler:

* ``submit(spec, tenant=..., priority=..., deadline=...)`` returns a
  future-style :class:`~repro.api.jobs.JobHandle` immediately;
* **admission control** decides *before any parameter I/O* whether a
  job's hard byte demand fits the global + per-tenant budget pool
  (reject or hold queued — never abort mid-execution for budget);
* the scheduler drains arrivals into **rolling scheduling windows**:
  jobs whose expert access sets overlap land in one window, are planned
  together (:func:`repro.core.planner.plan_batch`), and share one
  :class:`~repro.store.blockcache.CachingModelReader` scan and one
  opened packed layout — each selected expert block is physically read
  once per window (and, with the service's persistent cache, once per
  service lifetime);
* a global physical-byte pool is split across tenants by
  **weighted-fair arbitration** (per-tenant group caps in
  ``plan_batch``), with unused budget carried over to later windows;
* ``handle.cancel()`` aborts crash-safely through the transaction
  manager: the executor stops at its next checkpoint, staged output is
  discarded, and the transaction log stays clean — a subsequent
  identical submit commits bit-identically.

``Session.run_all`` is now a thin submit-all/wait-all wrapper over an
embedded (inline, unthreaded) service, golden-tested bit-identical with
identical per-category IOStats.  See docs/SERVICE.md.
"""
from __future__ import annotations

import os
import threading
import time
import warnings
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

from repro.api.budget import BudgetLike, BudgetSpec
from repro.api.jobs import (
    AdmissionRejected,
    DeadlineExceeded,
    JobCancelled,
    JobHandle,
    JobState,
)
from repro.api.spec import MergeSpec
from repro.core import blocks as blk
from repro.core import cost as cost_model
from repro.core.catalog import Catalog
from repro.api.workspace import WorkspaceOps
from repro.core.executor import (
    MergeCancelled,
    MergeResult,
    PipelineConfig,
    execute_merge,
)
from repro.core.plan import MergePlan
from repro.core.planner import BatchJob, plan_batch
from repro.core.transactions import TransactionManager
from repro.store.blockcache import CacheBudget, CachingModelReader
from repro.store.iostats import IOStats
from repro.store.journal import ResumeState
from repro.store.retry import RetryPolicy, is_transient
from repro.store.snapshot import SnapshotStore
from repro.testing.chaos import SimulatedCrash

#: default bound on the shared-read block cache (per window, or service-
#: wide in persistent-cache mode); misses beyond the cap stream uncached
DEFAULT_CACHE_MAX_BYTES = 1 << 30

#: retention bounds for an always-on service: terminal job records and
#: window-log entries beyond these are pruned (the catalog merge_job
#: table keeps the durable history; handles already returned stay valid)
RETAIN_TERMINAL_JOBS = 1024
RETAIN_WINDOW_LOG = 256


class _Node:
    """One DAG node scheduled for execution (deduped by spec_id)."""

    def __init__(self, spec: MergeSpec, sid_hint: Optional[str]):
        self.spec = spec
        self.sid_hint = sid_hint
        self.sid: Optional[str] = None
        self.result: Optional[MergeResult] = None


class _NodeCancel:
    """Composite cancel flag for a shared DAG node: fires only when no
    interested job still wants it (duck-types ``threading.Event.is_set``
    for the executor's checkpoints)."""

    __slots__ = ("_handles",)

    def __init__(self, handles: List[JobHandle]):
        self._handles = handles

    def is_set(self) -> bool:
        return not any(
            h.status not in JobState.TERMINAL and not h.cancel_requested
            for h in self._handles
        )


class WindowOptions:
    """Execution options shared by every job of one scheduling window
    (the former ``Session.run_all`` keyword surface)."""

    def __init__(
        self,
        shared_reads: bool = True,
        shared_budget: BudgetLike = None,
        compute: str = "pipelined",
        coalesce: bool = True,
        analyze: bool = True,
        cache_max_bytes: Union[int, None, str] = "auto",
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
        tier_billing: bool = False,
        verify=True,
        execution: str = "local",
        dist=None,
    ):
        self.shared_reads = shared_reads
        self.shared_budget = shared_budget
        self.compute = compute
        self.coalesce = coalesce
        self.analyze = analyze
        self.cache_max_bytes = (
            DEFAULT_CACHE_MAX_BYTES if cache_max_bytes == "auto" else cache_max_bytes
        )
        self.pipeline = pipeline
        self.prefer_packed = prefer_packed
        #: verify-on-read knob forwarded to execute_merge: True (default)
        #: enforces the block-integrity contract on every tier, a
        #: repro.store.integrity.VerifyPolicy opts tiers out selectively,
        #: False disables (trusted-local benchmarking only)
        self.verify = verify
        # tier-aware planner billing for remote-backed experts: warm-tier
        # blocks bill below full price, so a fixed budget admits more
        # blocks as caches fill.  Opt-in because it intentionally changes
        # block *selection* (better coverage per cold byte) — the default
        # keeps selections identical to the flat local path, which is
        # what bit-identity guarantees rely on.
        self.tier_billing = tier_billing
        #: "local" runs execute_merge in-process; "sharded" scatters each
        #: node across shard workers via repro.dist (docs/DISTRIBUTED.md)
        if execution not in ("local", "sharded"):
            raise ValueError(
                "execution must be 'local' or 'sharded', got %r" % execution)
        self.execution = execution
        #: repro.dist.DistOptions for execution="sharded" (None = defaults)
        self.dist = dist


#: default cap on executions per job before it is quarantined as poison
DEFAULT_MAX_JOB_ATTEMPTS = 3


class BudgetArbiter:
    """Global + per-tenant physical expert-byte pool (weighted fair).

    ``pool_b=None`` disables enforcement but keeps per-tenant usage
    accounting.  A tenant's share is ``pool * w_t / Σ w``; declare all
    tenants up front (``weights``) for stable shares — an undeclared
    tenant joins lazily at ``default_weight``, which re-divides the pool.
    ``reserve`` holds a hard byte demand from admission until the job's
    window realizes (or releases) it; ``charge`` records planned union
    bytes per tenant, which is exactly the physical I/O a shared-read
    window pays for that tenant (realized <= planned, §5.1).  Unused
    budget is never forfeited: remaining shares carry over to every
    later scheduling window.
    """

    def __init__(
        self,
        pool_b: Optional[int],
        weights: Optional[Mapping[str, float]] = None,
        default_weight: float = 1.0,
    ):
        self.pool_b = pool_b
        self.default_weight = float(default_weight)
        self._weights: Dict[str, float] = {
            t: float(w) for t, w in (weights or {}).items()
        }
        for t, w in self._weights.items():
            if w <= 0:
                raise ValueError(f"tenant {t!r} weight must be > 0, got {w}")
        self._lock = threading.Lock()
        self.spent: Dict[str, int] = {}
        self.reserved: Dict[str, int] = {}
        self.global_spent = 0

    @property
    def enabled(self) -> bool:
        return self.pool_b is not None

    def _ensure(self, tenant: str) -> None:
        if tenant not in self._weights:
            self._weights[tenant] = self.default_weight

    def _share(self, tenant: str) -> Optional[int]:
        if self.pool_b is None:
            return None
        self._ensure(tenant)
        total = sum(self._weights.values())
        return int(self.pool_b * self._weights[tenant] / total)

    def share(self, tenant: str) -> Optional[int]:
        with self._lock:
            return self._share(tenant)

    def _remaining(self, tenant: str) -> Optional[int]:
        share = self._share(tenant)
        if share is None:
            return None
        return max(
            0,
            share - self.spent.get(tenant, 0) - self.reserved.get(tenant, 0),
        )

    def remaining(self, tenant: str) -> Optional[int]:
        with self._lock:
            return self._remaining(tenant)

    def global_remaining(self) -> Optional[int]:
        if self.pool_b is None:
            return None
        with self._lock:
            return max(
                0,
                self.pool_b - self.global_spent - sum(self.reserved.values()),
            )

    def try_reserve(self, tenant: str, demand_b: int) -> Tuple[bool, Dict]:
        """Admission check for a hard byte demand; reserves on success.
        Returns (admitted, decision_record)."""
        with self._lock:
            rem_t = self._remaining(tenant)
            rem_g = (
                None
                if self.pool_b is None
                else max(
                    0,
                    self.pool_b
                    - self.global_spent
                    - sum(self.reserved.values()),
                )
            )
            record = {
                "kind": "hard",
                "demand_b": int(demand_b),
                "tenant_remaining_b": rem_t,
                "global_remaining_b": rem_g,
            }
            if rem_t is None:  # pool disabled: everything fits
                record["decision"] = "admit"
                return True, record
            if demand_b <= min(rem_t, rem_g):
                self.reserved[tenant] = self.reserved.get(tenant, 0) + int(
                    demand_b
                )
                record["decision"] = "admit"
                return True, record
            record["decision"] = "reject"
            return False, record

    def release(self, tenant: str, n: int) -> None:
        if n <= 0:
            return
        with self._lock:
            self.reserved[tenant] = max(0, self.reserved.get(tenant, 0) - n)

    def charge(self, tenant: str, n: int) -> None:
        if n <= 0:
            return
        with self._lock:
            self.spent[tenant] = self.spent.get(tenant, 0) + int(n)

    def charge_global(self, n: int) -> None:
        if n <= 0:
            return
        with self._lock:
            self.global_spent += int(n)

    def refund(self, tenant: str, n: int) -> None:
        """Return previously-charged bytes to a tenant's share — the
        resume path: a re-attempted node is charged its full planned
        union by ``plan_batch`` accounting, but the journaled prefix was
        already paid for by the dead attempt, so crash + resume must
        charge each expert byte once."""
        if n <= 0:
            return
        with self._lock:
            self.spent[tenant] = max(0, self.spent.get(tenant, 0) - int(n))

    def refund_global(self, n: int) -> None:
        if n <= 0:
            return
        with self._lock:
            self.global_spent = max(0, self.global_spent - int(n))

    def usage(self) -> Dict:
        with self._lock:
            tenants = sorted(
                set(self._weights) | set(self.spent) | set(self.reserved)
            )
            return {
                "pool_b": self.pool_b,
                "global_spent_b": self.global_spent,
                "tenants": {
                    t: {
                        "weight": self._weights.get(t, self.default_weight),
                        "share_b": self._share(t),
                        "spent_b": self.spent.get(t, 0),
                        "reserved_b": self.reserved.get(t, 0),
                    }
                    for t in tenants
                },
            }


class _Job:
    """Internal scheduler record for one submitted handle."""

    __slots__ = ("handle", "opts", "group", "seq", "reserved_b",
                 "deadline_at", "attempts", "not_before")

    def __init__(self, handle: JobHandle, opts: WindowOptions,
                 group: Optional[str], seq: int, attempts: int = 0):
        self.handle = handle
        self.opts = opts
        self.group = group  # atomic-window token (run_all batches)
        self.seq = seq
        self.reserved_b = 0
        #: executions so far (carried across service restarts via the
        #: catalog row) — the poison-quarantine counter
        self.attempts = int(attempts)
        #: jittered retry backoff: admission skips this job until then
        self.not_before = 0.0
        self.deadline_at: Optional[float] = (
            None
            if handle.deadline is None
            else handle.submitted_at + float(handle.deadline)
        )


class MergeService(WorkspaceOps):
    """Long-lived, thread-backed merge scheduler (see module docstring).

    Standalone construction opens (or joins) a workspace::

        with MergeService("/path/ws", budget="2GiB",
                          tenants={"prod": 3.0, "batch": 1.0}) as svc:
            h = svc.submit(spec, tenant="prod", priority=5)
            result = h.wait()

    ``start=False`` creates an *inline* service: jobs run on the caller
    thread inside :meth:`drain` — this is how ``Session.run_all``
    embeds one (no scheduler thread, no behavior change, bit-identical
    I/O), and how tests make scheduling deterministic.
    """

    def __init__(
        self,
        workspace: str,
        block_size: int = blk.DEFAULT_BLOCK_SIZE,
        stats: Optional[IOStats] = None,
        recover: bool = True,
        budget: BudgetLike = None,
        tenants: Optional[Mapping[str, float]] = None,
        admission: str = "reject",
        shared_reads: bool = True,
        compute: str = "pipelined",
        coalesce: bool = True,
        analyze: bool = True,
        cache_max_bytes: Union[int, None, str] = "auto",
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
        tier_billing: bool = False,
        persistent_cache: bool = True,
        max_window_jobs: int = 16,
        max_open_readers: int = 64,
        poll_s: float = 0.05,
        start: bool = True,
        disk_cache_max_bytes: Optional[int] = None,
        max_job_attempts: int = DEFAULT_MAX_JOB_ATTEMPTS,
        verify=True,
        scrub_idle_s: Optional[float] = None,
        scrub_rate_mbps: float = 0.0,
    ):
        # scoped I/O accounting: a service gets its own IOStats unless
        # the caller opts into a shared (e.g. GLOBAL_STATS) instance
        stats = stats if stats is not None else IOStats()
        os.makedirs(workspace, exist_ok=True)
        snapshots = SnapshotStore(
            workspace, stats, disk_cache_max_bytes=disk_cache_max_bytes
        )
        catalog = Catalog(os.path.join(workspace, "catalog.sqlite"), stats)
        snapshots.models.add_delete_guard(catalog.model_references)
        txn = TransactionManager(snapshots, catalog)
        recovery = txn.recover() if recover else None
        self._init_parts(
            snapshots, catalog, txn, block_size, stats,
            budget=budget, tenants=tenants, admission=admission,
            shared_reads=shared_reads, compute=compute, coalesce=coalesce,
            analyze=analyze, cache_max_bytes=cache_max_bytes,
            pipeline=pipeline, prefer_packed=prefer_packed,
            tier_billing=tier_billing,
            persistent_cache=persistent_cache,
            max_window_jobs=max_window_jobs,
            max_open_readers=max_open_readers, poll_s=poll_s,
            owns_substrate=True,
            max_job_attempts=max_job_attempts,
            verify=verify,
            scrub_idle_s=scrub_idle_s,
            scrub_rate_mbps=scrub_rate_mbps,
        )
        if recovery is not None:
            self._resume_states.update(recovery.get("resumable", {}))
            self._readopt()
        if start:
            self.start()

    @classmethod
    def _from_parts(
        cls,
        snapshots: SnapshotStore,
        catalog: Catalog,
        txn: TransactionManager,
        block_size: int,
        stats: IOStats,
        **opts,
    ) -> "MergeService":
        """Wrap an existing substrate (Session embedding) without
        re-opening stores or re-running recovery."""
        svc = cls.__new__(cls)
        svc._init_parts(
            snapshots, catalog, txn, block_size, stats,
            owns_substrate=False, **opts,
        )
        return svc

    def _init_parts(
        self,
        snapshots: SnapshotStore,
        catalog: Catalog,
        txn: TransactionManager,
        block_size: int,
        stats: IOStats,
        budget: BudgetLike = None,
        tenants: Optional[Mapping[str, float]] = None,
        admission: str = "reject",
        shared_reads: bool = True,
        compute: str = "pipelined",
        coalesce: bool = True,
        analyze: bool = True,
        cache_max_bytes: Union[int, None, str] = "auto",
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
        tier_billing: bool = False,
        persistent_cache: bool = True,
        max_window_jobs: int = 16,
        max_open_readers: int = 64,
        poll_s: float = 0.05,
        owns_substrate: bool = True,
        max_job_attempts: int = DEFAULT_MAX_JOB_ATTEMPTS,
        verify=True,
        scrub_idle_s: Optional[float] = None,
        scrub_rate_mbps: float = 0.0,
    ) -> None:
        self.snapshots = snapshots
        self.catalog = catalog
        self.txn = txn
        self.block_size = block_size
        self.stats = stats
        self.workspace = snapshots.workspace
        self._owns_substrate = owns_substrate

        pool_spec = BudgetSpec.parse(budget)
        if pool_spec.kind == "fraction":
            raise ValueError(
                "the MergeService budget pool needs an absolute size "
                "('2GiB', bytes, ...) — a fraction has no stable reference "
                "set in a continuously-scheduling service"
            )
        if admission not in ("reject", "queue"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.admission = admission
        self.arbiter = BudgetArbiter(pool_spec.resolve(), tenants)
        self.defaults = WindowOptions(
            shared_reads=shared_reads, compute=compute, coalesce=coalesce,
            analyze=analyze, cache_max_bytes=cache_max_bytes,
            pipeline=pipeline, prefer_packed=prefer_packed,
            tier_billing=tier_billing, verify=verify,
        )
        #: idle-time background scrub (mergefsck): when set, an idle
        #: scheduler runs a repairing fsck pass over the workspace every
        #: ``scrub_idle_s`` seconds of quiet — the ZFS-scrub counterpart
        #: to verify-on-read, catching rot in data no merge is touching
        self.scrub_idle_s = scrub_idle_s
        self.scrub_rate_mbps = float(scrub_rate_mbps)
        self._last_scrub = time.monotonic()  # scheduler thread only
        self._scrub_report: Optional[Dict[str, Any]] = None  # guarded-by: _cond
        self.persistent_cache = persistent_cache
        self.max_window_jobs = max(1, int(max_window_jobs))
        self.max_open_readers = max(1, int(max_open_readers))
        self.poll_s = poll_s
        self.max_job_attempts = max(1, int(max_job_attempts))
        #: jittered backoff between retry attempts of transiently-failed
        #: jobs (full jitter; shared with the remote store's retry story)
        self.retry_policy = RetryPolicy(
            attempts=self.max_job_attempts, base_backoff_s=0.01
        )
        #: sid -> validated ResumeState for crashed-but-resumable merges
        #: (from startup recovery, or stashed live after a worker death)
        self._resume_states: Dict[str, ResumeState] = {}

        self._cond = threading.Condition()
        self._pending: List[_Job] = []  # guarded-by: _cond
        self._jobs: Dict[str, _Job] = {}  # guarded-by: _cond
        self._seq = 0  # guarded-by: _cond
        self._window_seq = 0
        self.window_log: List[Dict] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False

        # persistent shared-read cache: one bounded budget for the whole
        # service; readers/layouts stay open across scheduling windows so
        # overlapping *in-flight* work shares one physical scan
        self._cache_budget = CacheBudget(self.defaults.cache_max_bytes)
        self._readers: Dict[Tuple[Optional[str], str], CachingModelReader] = {}
        self._layouts: Dict[str, Any] = {}

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "MergeService":
        """Start the scheduler thread (idempotent)."""
        if self._closed:
            raise RuntimeError("MergeService already closed")
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="mergepipe-scheduler", daemon=True
            )
            self._thread.start()
        return self

    @property
    def started(self) -> bool:
        return self._thread is not None

    def __enter__(self) -> "MergeService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(cancel_pending=exc_type is not None)

    def close(
        self, cancel_pending: bool = False, timeout: Optional[float] = None
    ) -> None:
        """Stop the service.  By default drains: waits for every
        submitted job to reach a terminal state first.
        ``cancel_pending=True`` instead cancels queued jobs and requests
        cooperative abort of running ones.  Idempotent."""
        if self._closed:
            return
        if cancel_pending:
            with self._cond:
                jobs = list(self._jobs.values())
            for job in jobs:
                job.handle.cancel()
        else:
            try:
                self.drain(timeout=timeout)
            except TimeoutError:
                pass
        # whatever drain could not finish (admission-held jobs, timeout
        # leftovers) is cancelled now: close() never strands a waiter on
        # a handle that can no longer reach a terminal state
        with self._cond:
            jobs = list(self._jobs.values())
        for job in jobs:
            if job.handle.status not in JobState.TERMINAL:
                job.handle.cancel()
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None
        self._closed = True
        for r in self._readers.values():
            r.close()
        self._readers.clear()
        for layout in self._layouts.values():
            layout.close()
        self._layouts.clear()
        if self._owns_substrate:
            self.catalog.close()

    def _loop(self) -> None:
        try:
            while not self._stop.is_set():
                try:
                    busy = self._cycle()
                # broad-except-ok: the scheduler thread must outlive any
                # cycle failure (every live handle is settled with the
                # error); MergeCancelled is settled per-node inside
                # _run_level and cannot reach here, and SimulatedCrash is
                # a BaseException this handler deliberately cannot see
                except Exception as e:
                    with self._cond:
                        jobs = list(self._jobs.values())
                        self._pending.clear()
                    for job in jobs:
                        if job.handle.status not in JobState.TERMINAL:
                            self._fail_handle(job.handle, e)
                    busy = False
                if not busy:
                    self._maybe_scrub()
                    # nothing ran this cycle: any pending jobs are
                    # admission-held — sleep until a submit notifies or
                    # the poll interval re-checks admission (no spin)
                    with self._cond:
                        if not self._stop.is_set():
                            self._cond.wait(timeout=self.poll_s)
                else:
                    # work ran: push the next idle scrub out a full
                    # interval so scrubbing never competes with merges
                    self._last_scrub = time.monotonic()
        finally:
            self.catalog.close()  # this thread's sqlite connection

    # --------------------------------------------------------------- submit
    def submit(
        self,
        spec: Union[MergeSpec, Dict],
        sid: Optional[str] = None,
        tenant: str = "default",
        priority: int = 0,
        deadline: Optional[float] = None,
        job_id: Optional[str] = None,
        _opts: Optional[WindowOptions] = None,
        _group: Optional[str] = None,
        _attempts: int = 0,
    ) -> JobHandle:
        """Submit one merge job; returns immediately with a JobHandle.

        ``tenant`` scopes the job under the budget arbiter's weighted
        shares; ``priority`` (higher first) and ``deadline`` (relative
        seconds; the job fails with :class:`DeadlineExceeded` if no
        window ran it in time) order the scheduling queue.
        """
        if self._closed:
            raise RuntimeError("MergeService already closed")
        if isinstance(spec, dict):
            spec = MergeSpec.from_dict(spec)
        handle = JobHandle(
            spec, sid=sid, tenant=tenant, priority=priority,
            deadline=deadline, job_id=job_id,
        )
        handle.submitted_at = time.time()
        handle._service = self
        handle._set_state(JobState.QUEUED)
        job = _Job(
            handle, _opts or self.defaults, _group, self._next_seq(),
            attempts=_attempts,
        )
        # the spec is persisted at submit (not first execution) so a
        # service restart can re-adopt jobs that never reached a window
        self.catalog.record_spec(
            spec.spec_id, spec.name, spec.op, spec.to_dict()
        )
        self.catalog.record_job(
            handle.job_id, spec.spec_id, tenant, priority, JobState.QUEUED,
            sid=sid or spec.name, deadline=job.deadline_at,
            attempts=_attempts,
        )
        with self._cond:
            self._pending.append(job)
            self._jobs[handle.job_id] = job
            self._cond.notify_all()
        return handle

    def _next_seq(self) -> int:
        with self._cond:
            self._seq += 1
            return self._seq

    # ----------------------------------------------------- restart recovery
    def _readopt(self) -> None:
        """Re-adopt catalog job rows a dead service process left
        non-terminal (queued / admitted / running): each is re-submitted
        under its original job id, tenant, and priority, replaying the
        spec persisted at submit time.  A job whose sid has a validated
        progress journal resumes at its block-level high-water mark; one
        that already burned ``max_job_attempts`` executions is
        quarantined instead of being retried forever."""
        rows: List[Dict] = []
        for state in (JobState.QUEUED, JobState.ADMITTED, JobState.RUNNING):
            rows.extend(self.catalog.list_jobs(state=state))
        for row in rows:
            attempts = int(row.get("attempts") or 0)
            if attempts >= self.max_job_attempts:
                self._quarantine_row(
                    row,
                    f"{attempts} execution(s) died without committing",
                )
                continue
            spec_doc = self.catalog.get_spec(row["spec_id"])
            if spec_doc is None:
                self._quarantine_row(row, "spec payload missing from catalog")
                continue
            deadline = None
            if row.get("deadline") is not None:
                # catalog rows store the absolute instant; submit() takes
                # relative seconds — an already-missed deadline re-enters
                # at zero and fails cleanly at the next admission pass
                deadline = max(0.0, float(row["deadline"]) - time.time())
            self.submit(
                MergeSpec.from_dict(spec_doc["payload"]),
                sid=row.get("sid"),
                tenant=row["tenant"],
                priority=row["priority"],
                deadline=deadline,
                job_id=row["job_id"],
                _attempts=attempts,
            )

    def _quarantine_row(self, row: Dict, why: str) -> None:
        sid = row.get("sid")
        state = self._resume_states.pop(sid, None) if sid else None
        if state is not None:
            state.discard()
        self.catalog.update_job(
            row["job_id"], state=JobState.QUARANTINED,
            error=f"quarantined at restart: {why}",
            finished_at=time.time(),
        )

    # --------------------------------------------------------------- cancel
    def _cancel_job(self, handle: JobHandle) -> bool:
        """JobHandle.cancel() backend: dequeue a queued job immediately,
        flag a running one for cooperative abort."""
        dequeued = None
        with self._cond:
            job = self._jobs.get(handle.job_id)
            if job is not None and job in self._pending:
                self._pending.remove(job)
                dequeued = job
        if dequeued is not None:
            # once off the pending queue the job is exclusively ours —
            # settle it outside _cond: the catalog write is blocking
            # sqlite I/O and must not stall the scheduler lock
            self._settle_reservation(dequeued)
            # row first, handle second (see _fail_handle)
            finished_at = time.time()
            self.catalog.update_job(
                handle.job_id, state=JobState.CANCELLED,
                finished_at=finished_at,
            )
            handle._fail(
                JobCancelled(f"job {handle.job_id} was cancelled"),
                state=JobState.CANCELLED,
                finished_at=finished_at,
            )
            return True
        if handle.status in JobState.TERMINAL:
            return False
        handle._cancel_event.set()
        return True

    def _settle_reservation(self, job: _Job) -> None:
        if job.reserved_b:
            self.arbiter.release(job.handle.tenant, job.reserved_b)
            job.reserved_b = 0

    # ----------------------------------------------------------------- wait
    def wait_all(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted job reaches a terminal state."""
        deadline = None if timeout is None else time.time() + timeout
        with self._cond:
            jobs = list(self._jobs.values())
        for job in jobs:
            left = None if deadline is None else max(0.0, deadline - time.time())
            if not job.handle._terminal.wait(left):
                raise TimeoutError(
                    f"job {job.handle.job_id} still {job.handle.status}"
                )

    def drain(self, timeout: Optional[float] = None) -> None:
        """Run (inline mode) or wait for (threaded mode) the scheduler
        until no submitted job remains non-terminal.  Jobs held back by
        ``admission='queue'`` stay queued — drain does not force them."""
        if self._thread is None:
            while True:
                if self._cycle():
                    continue
                # nothing ran — but a job requeued after a transient
                # crash may just be waiting out its backoff
                delay = self._retry_delay_s()
                if delay is None:
                    return
                time.sleep(delay)
        else:
            deadline = None if timeout is None else time.time() + timeout
            while True:
                with self._cond:
                    jobs = list(self._jobs.values())
                live = [
                    j for j in jobs
                    if j.handle.status not in JobState.TERMINAL
                    and not self._is_parked(j)
                ]
                if not live:
                    return
                if deadline is not None and time.time() > deadline:
                    raise TimeoutError(
                        f"{len(live)} job(s) still live after {timeout}s"
                    )
                live[0].handle._terminal.wait(timeout=self.poll_s)

    def _retry_delay_s(self) -> Optional[float]:
        """Inline mode: seconds until the earliest backed-off retry is
        due, or None when no pending job is waiting on a retry (held or
        terminal jobs don't count — drain never forces those)."""
        now = time.time()
        with self._cond:
            waits = [
                j.not_before - now
                for j in self._pending
                if j.not_before > 0
                and j.handle.status not in JobState.TERMINAL
                and (j.handle.admission or {}).get("decision") != "hold"
            ]
        if not waits:
            return None
        return max(0.0, min(waits)) + 0.001

    def _is_parked(self, job: _Job) -> bool:
        """True for queue-policy jobs admission is still holding back."""
        with self._cond:
            return job in self._pending and (
                job.handle.admission or {}
            ).get("decision") == "hold"

    # ============================================================ scheduler
    def _cycle(self) -> bool:
        """One scheduler iteration: admit, window, execute.  Returns
        True when any window ran."""
        ready = self._admit_and_take()
        if not ready:
            return False
        for window_jobs, opts in self._windows(ready):
            self._run_window(window_jobs, opts)
        self._prune()
        return True

    def _prune(self) -> None:
        """Bound in-memory retention (always-on services): drop the
        oldest terminal job records beyond RETAIN_TERMINAL_JOBS and trim
        the window log.  The catalog's merge_job table keeps the durable
        history; caller-held handles are unaffected."""
        with self._cond:
            terminal = [
                jid for jid, job in self._jobs.items()
                if job.handle.status in JobState.TERMINAL
            ]
            for jid in terminal[:max(0, len(terminal) - RETAIN_TERMINAL_JOBS)]:
                del self._jobs[jid]
        if len(self.window_log) > RETAIN_WINDOW_LOG:
            del self.window_log[:len(self.window_log) - RETAIN_WINDOW_LOG]

    # ---------------------------------------------------------- admission
    def _hard_demand_b(self, spec: MergeSpec) -> Optional[int]:
        """A job's *hard* byte demand: the sum of absolute byte budgets
        across its spec graph.  Fraction/unbounded budgets are elastic —
        the window planner scales them into whatever share arbitration
        grants — so they carry no admission demand."""
        total = 0
        seen = False
        for node in spec.walk():
            if node.budget.kind == "bytes":
                total += int(node.budget.value)
                seen = True
        return total if seen else None

    def _admit_and_take(self) -> List[_Job]:
        """Admission control over the queued jobs; returns those cleared
        for scheduling (removed from the pending queue)."""
        taken: List[_Job] = []
        #: jobs settled terminal by admission this cycle; their handle
        #: _fail + catalog row land after _cond is released — the
        #: catalog write is blocking sqlite I/O and submit()/cancel()
        #: must not stall on the scheduler lock behind it
        settled: List[Tuple[_Job, BaseException, str, Optional[Dict]]] = []
        now = time.time()
        with self._cond:
            still_pending: List[_Job] = []
            for job in self._pending:
                handle = job.handle
                if handle.status in JobState.TERMINAL:
                    continue  # cancelled while queued
                if job.deadline_at is not None and now > job.deadline_at:
                    settled.append((job, DeadlineExceeded(
                        f"job {handle.job_id} missed its deadline before "
                        f"a scheduling window could run it"
                    ), JobState.FAILED, None))
                    continue
                if job.not_before > now:
                    # requeued after a transient crash: still waiting out
                    # its jittered backoff
                    still_pending.append(job)
                    continue
                demand = self._hard_demand_b(handle.spec)
                if not self.arbiter.enabled:
                    handle.admission = {"decision": "admit", "kind": "elastic"}
                elif demand is None:
                    # elastic demands scale into the tenant's remaining
                    # share — but an exhausted pool must reject (or hold)
                    # them here, not plan them down to a degenerate
                    # zero-budget merge that commits "successfully".
                    # "Exhausted" means less than one block left: the
                    # planner could not select anything with it.
                    rem_t = self.arbiter.remaining(handle.tenant)
                    rem_g = self.arbiter.global_remaining()
                    record = {
                        "kind": "elastic",
                        "tenant_remaining_b": rem_t,
                        "global_remaining_b": rem_g,
                    }
                    if min(rem_t, rem_g) < self.block_size:
                        if self.admission == "queue":
                            record["decision"] = "hold"
                            handle.admission = record
                            still_pending.append(job)
                            continue
                        record["decision"] = "reject"
                        handle.admission = record
                        settled.append((job, AdmissionRejected(
                            f"job {handle.job_id} is elastic but tenant "
                            f"{handle.tenant!r} has no budget pool left"
                        ), JobState.REJECTED, record))
                        continue
                    record["decision"] = "admit"
                    handle.admission = record
                else:
                    ok, record = self.arbiter.try_reserve(
                        handle.tenant, demand
                    )
                    if ok:
                        job.reserved_b = demand
                        handle.admission = record
                    elif self.admission == "queue":
                        record["decision"] = "hold"
                        handle.admission = record
                        still_pending.append(job)
                        continue
                    else:
                        handle.admission = record
                        settled.append((job, AdmissionRejected(
                            f"job {handle.job_id} demands "
                            f"{demand} expert bytes but tenant "
                            f"{handle.tenant!r} has "
                            f"{record['tenant_remaining_b']} of the "
                            f"pool left"
                        ), JobState.REJECTED, record))
                        continue
                # the transient ADMITTED state lives on the handle only;
                # the catalog records admission with the terminal row
                # (one less commit per job on the batch path)
                handle._set_state(JobState.ADMITTED)
                taken.append(job)
            self._pending = still_pending
        for job, error, state, record in settled:
            handle = job.handle
            self._settle_reservation(job)
            handle._fail(error, state=state)
            if state == JobState.REJECTED:
                self.catalog.update_job(
                    handle.job_id, state=state, admission=record,
                    finished_at=handle.finished_at,
                )
            else:
                self.catalog.update_job(
                    handle.job_id, state=state,
                    error="deadline exceeded",
                    finished_at=handle.finished_at,
                )
        return taken

    # ---------------------------------------------------------- windowing
    def _access_keys(self, job: _Job) -> List[str]:
        """Grouping keys: the job's leaf expert access set plus its
        target snapshot ids (so sid conflicts meet in one window and are
        rejected by validation, like the old batch barrier)."""
        keys: List[str] = []
        for node in job.handle.spec.walk():
            for e in node.experts:
                if isinstance(e, str):
                    keys.append(f"m:{e}")
            if node.name:
                keys.append(f"s:{node.name}")
        if job.handle.requested_sid:
            keys.append(f"s:{job.handle.requested_sid}")
        return keys

    def _windows(
        self, ready: List[_Job]
    ) -> List[Tuple[List[_Job], WindowOptions]]:
        """Partition admitted jobs into scheduling windows.

        Jobs submitted as one atomic group (``run_all`` batches) form
        exactly one window.  Remaining jobs are grouped by overlap of
        their expert access sets (union-find): overlapping jobs share a
        window — hence one CachingModelReader scan — while disjoint jobs
        roll into separate windows.  Jobs only share a window when their
        execution options object is the same."""
        explicit: Dict[str, List[_Job]] = {}
        rest: List[_Job] = []
        for job in ready:
            if job.group is not None:
                explicit.setdefault(job.group, []).append(job)
            else:
                rest.append(job)

        # (window, atomic): atomic groups (run_all batches) must stay one
        # window whatever their size — chunking would fragment the joint
        # plan, the pooled budget, and batch-wide sid validation
        windows: List[Tuple[List[_Job], bool]] = [
            (sorted(jobs, key=lambda j: j.seq), True)
            for jobs in explicit.values()
        ]

        # union-find over access keys, partitioned by options identity
        by_opts: Dict[int, List[_Job]] = {}
        for job in rest:
            by_opts.setdefault(id(job.opts), []).append(job)
        for bucket in by_opts.values():
            parent: Dict[str, str] = {}

            def find(x: str) -> str:
                while parent.setdefault(x, x) != x:
                    parent[x] = parent[parent[x]]
                    x = parent[x]
                return x

            def union(a: str, b: str) -> None:
                parent[find(a)] = find(b)

            roots: Dict[int, str] = {}
            for job in bucket:
                keys = self._access_keys(job) or [f"j:{job.handle.job_id}"]
                for k in keys[1:]:
                    union(keys[0], k)
                roots[job.seq] = keys[0]
            comps: Dict[str, List[_Job]] = {}
            for job in bucket:
                comps.setdefault(find(roots[job.seq]), []).append(job)
            windows.extend(
                (sorted(c, key=lambda j: j.seq), False)
                for c in comps.values()
            )

        # higher priority windows first; earliest-deadline, then arrival
        def job_key(j: _Job):
            return (-j.handle.priority,
                    j.deadline_at if j.deadline_at is not None else float("inf"),
                    j.seq)

        out: List[Tuple[List[_Job], WindowOptions]] = []
        for w, atomic in sorted(
            windows, key=lambda w: min(job_key(j) for j in w[0])
        ):
            w = sorted(w, key=job_key)
            if atomic:
                out.append((w, w[0].opts))
                continue
            for i in range(0, len(w), self.max_window_jobs):
                chunk = w[i:i + self.max_window_jobs]
                out.append((chunk, chunk[0].opts))
        return out

    # ===================================================== window execution
    def _run_window(self, wjobs: List[_Job], opts: WindowOptions) -> None:
        """Execute one scheduling window: the former ``run_all`` batch
        body (DAG expansion, sid validation/adoption, level-ordered
        planning + shared-read execution) extended with budget
        arbitration, cooperative cancellation, and progress events."""
        wjobs = [j for j in wjobs if j.handle.status not in JobState.TERMINAL]
        # admission re-check at window time: windows earlier in this same
        # scheduler cycle may have drained the pool since _admit_and_take
        # cleared these jobs — an elastic job whose share is now below one
        # block must reject here, not plan down to a degenerate zero-
        # budget merge (hard demands hold their reservation, so they keep
        # their headroom by construction)
        if self.arbiter.enabled:
            still: List[_Job] = []
            for job in wjobs:
                handle = job.handle
                if job.reserved_b == 0 and self._hard_demand_b(handle.spec) is None:
                    rem = min(
                        self.arbiter.remaining(handle.tenant),
                        self.arbiter.global_remaining(),
                    )
                    if rem < self.block_size:
                        record = dict(handle.admission or {})
                        record.update(
                            decision="reject", kind="elastic",
                            tenant_remaining_b=self.arbiter.remaining(
                                handle.tenant
                            ),
                        )
                        handle.admission = record
                        handle._fail(
                            AdmissionRejected(
                                f"job {handle.job_id}: tenant "
                                f"{handle.tenant!r} exhausted its budget "
                                f"pool before this scheduling window"
                            ),
                            state=JobState.REJECTED,
                        )
                        self.catalog.update_job(
                            handle.job_id, state=JobState.REJECTED,
                            admission=record,
                            finished_at=handle.finished_at,
                        )
                        continue
                still.append(job)
            wjobs = still
        if not wjobs:
            return
        self._window_seq += 1
        window_id = f"win-{self._window_seq:06d}"
        running_updates = []
        for job in wjobs:
            # this window realizes (or forfeits) any admission hold
            self._settle_reservation(job)
            job.attempts += 1
            job.handle.window_id = window_id
            job.handle._set_state(JobState.RUNNING)
            running_updates.append((
                job.handle.job_id,
                {"state": JobState.RUNNING, "window_id": window_id,
                 "attempts": job.attempts},
            ))
        self.catalog.update_jobs(running_updates)

        # -- 1. expand spec DAGs, dedupe shared subgraphs by content ------
        nodes: Dict[str, _Node] = {}
        alias_roots: List[_Node] = []
        job_nodes: Dict[str, _Node] = {}
        interested: Dict[int, List[JobHandle]] = {}
        for job in wjobs:
            handle = job.handle
            walked: List[_Node] = []
            for spec in handle.spec.walk():
                node = nodes.get(spec.spec_id)
                if node is None:
                    nodes[spec.spec_id] = node = _Node(spec, spec.name)
                walked.append(node)
            root = nodes[handle.spec.spec_id]
            if handle.requested_sid:
                if root.sid_hint and root.sid_hint != handle.requested_sid:
                    # same content already claimed under another sid: the
                    # user asked for a distinct snapshot — execute again
                    # under its own name (children still dedupe).
                    root = _Node(handle.spec, handle.requested_sid)
                    alias_roots.append(root)
                    walked[-1] = root
                else:
                    root.sid_hint = handle.requested_sid
            job_nodes[handle.job_id] = root
            for node in walked:
                interested.setdefault(id(node), []).append(handle)

        all_nodes = [*nodes.values(), *alias_roots]
        try:
            self._validate_sids(all_nodes, opts)
        except ValueError as e:
            self._fail_window(wjobs, e)
            return

        # -- 3. execute level by level (children before parents) ----------
        by_level: Dict[int, List[_Node]] = {}
        for node in all_nodes:
            if node.result is None:  # adopted snapshots skip execution
                by_level.setdefault(node.spec.depth(), []).append(node)
        dead: Dict[int, BaseException] = {}
        window_stats: Dict[str, Any] = {}
        try:
            for level in sorted(by_level):
                window_stats = self._run_level(
                    by_level[level], nodes, opts, interested, dead,
                )
        # broad-except-ok: level-infrastructure failure (per-node errors,
        # incl. MergeCancelled, are contained inside _run_level); every
        # unresolved handle in the window is settled with the error, and
        # SimulatedCrash stays invisible to this handler by design
        except Exception as e:
            self._fail_window(wjobs, e)
            return
        finally:
            self.window_log.append({
                "window_id": window_id,
                "jobs": [j.handle.job_id for j in wjobs],
                "tenants": sorted({j.handle.tenant for j in wjobs}),
                "stats": window_stats,
            })

        # -- 4. resolve handles -------------------------------------------
        done_updates = []
        finishes: List[Tuple[JobHandle, _Node]] = []
        finished_at = time.time()
        for job in wjobs:
            handle = job.handle
            if handle.status in JobState.TERMINAL:
                continue  # cancelled/failed during level execution
            if handle.status == JobState.QUEUED:
                continue  # requeued for a later attempt (transient crash)
            if handle.cancel_requested:
                # the node may still have completed for OTHER jobs that
                # dedupe to it — this handle's cancel() contract holds
                # regardless: wait() raises, status is cancelled
                self._fail_handle(
                    handle,
                    JobCancelled(f"job {handle.job_id} was cancelled"),
                )
                continue
            node = job_nodes[handle.job_id]
            if node.result is not None:
                finishes.append((handle, node))
                done_updates.append((
                    handle.job_id,
                    {"state": JobState.DONE, "sid": node.sid,
                     "admission": handle.admission,
                     "finished_at": finished_at},
                ))
            else:
                err = dead.get(id(node)) or RuntimeError(
                    f"node {node.spec.spec_id} did not execute"
                )
                self._fail_handle(handle, err)
        # rows committed (one batch) before any waiter is woken — same
        # ordering contract as _fail_handle
        self.catalog.update_jobs(done_updates)
        for handle, node in finishes:
            handle._finish(node.result, finished_at=finished_at)

    def _fail_window(self, wjobs: List[_Job], error: BaseException) -> None:
        for job in wjobs:
            if job.handle.status not in JobState.TERMINAL:
                self._fail_handle(job.handle, error)

    def _fail_handle(self, handle: JobHandle, error: BaseException) -> None:
        cancelled = isinstance(error, (MergeCancelled, JobCancelled))
        state = JobState.CANCELLED if cancelled else JobState.FAILED
        # catalog row BEFORE waking the waiter: a thread returning from
        # wait() must find the terminal row already committed, or it can
        # observe status==CANCELLED while the row still says running
        finished_at = time.time()
        self.catalog.update_job(
            handle.job_id, state=state, error=str(error),
            finished_at=finished_at,
        )
        handle._fail(
            error if not cancelled or isinstance(error, JobCancelled)
            else JobCancelled(str(error)),
            state=state,
            finished_at=finished_at,
        )

    def _requeue_or_quarantine(
        self,
        node: _Node,
        handles: List[JobHandle],
        error: BaseException,
        dead: Dict[int, BaseException],
    ) -> None:
        """After a transient worker death: send each surviving job back
        to the scheduling queue with jittered backoff, or move it to the
        terminal ``quarantined`` state once it has burned
        ``max_job_attempts`` executions (poison work that keeps killing
        workers must not be retried forever)."""
        updates: List[Tuple[str, Dict[str, Any]]] = []
        requeued = 0
        now = time.time()
        for h in handles:
            if h.status in JobState.TERMINAL or h.cancel_requested:
                continue
            with self._cond:
                job = self._jobs.get(h.job_id)
            if job is None or job.attempts >= self.max_job_attempts:
                quarantine_err = RuntimeError(
                    f"job {h.job_id} quarantined after "
                    f"{job.attempts if job else '?'} execution(s) died: "
                    f"{error}"
                )
                # chain the final attempt's failure so callers can
                # introspect the typed cause (e.g. CorruptBlockError
                # provenance after an unrepairable-source merge)
                quarantine_err.__cause__ = error
                updates.append((h.job_id, {
                    "state": JobState.QUARANTINED,
                    "error": str(quarantine_err),
                    "finished_at": now,
                }))
                h._fail(
                    quarantine_err, state=JobState.QUARANTINED,
                    finished_at=now,
                )
                continue
            job.not_before = now + self.retry_policy.backoff_s(
                job.attempts - 1
            )
            h._set_state(JobState.QUEUED)
            updates.append((h.job_id, {
                "state": JobState.QUEUED, "error": str(error),
            }))
            with self._cond:
                if job not in self._pending:
                    self._pending.append(job)
                self._cond.notify_all()
            requeued += 1
        self.catalog.update_jobs(updates)
        if not requeued:
            # nobody left to retry this node: dependents must fail too
            dead[id(node)] = (
                error if isinstance(error, Exception)
                else RuntimeError(str(error))
            )

    # ----------------------------------------------------- sid validation
    def _validate_sids(
        self, all_nodes: List[_Node], opts: WindowOptions
    ) -> None:
        """Validate target snapshot ids before any work; adopt committed
        snapshots produced by the exact same spec (incremental graph
        composition across windows)."""
        claimed: Dict[str, _Node] = {}
        for node in all_nodes:
            hint = node.sid_hint
            if not hint:
                continue
            other = claimed.get(hint)
            if other is not None and other is not node:
                raise ValueError(
                    f"two different merge jobs target snapshot id {hint!r} "
                    f"(specs {other.spec.spec_id} and {node.spec.spec_id})"
                )
            claimed[hint] = node
            if self.snapshots.is_published(hint):
                man = self.catalog.get_manifest(hint)
                plan = (
                    self.catalog.get_plan(man["plan_id"]) if man else None
                )
                committed_spec = (plan or {}).get("payload", {}).get("spec_id")
                if committed_spec == node.spec.spec_id:
                    node.sid = hint
                    # stats keep the executor's standard shape so legacy
                    # callers reading seconds/plan/etc. keep working
                    node.result = MergeResult(
                        hint, man,
                        {"seconds": 0.0, "c_expert_run": 0,
                         "c_expert_hat": (plan or {}).get("c_expert_hat", 0),
                         "realized_expert_blocks": 0,
                         "compute": opts.compute, "coalesce": opts.coalesce,
                         "reused_snapshot": True,
                         "plan": {"reused": True, "plan_seconds": 0.0}},
                    )
                    continue
                raise ValueError(
                    f"snapshot {hint!r} already published in this workspace "
                    f"by a different spec; pick a fresh sid/name"
                )

    # ------------------------------------------------------------- levels
    def _resolve_input(
        self, inp: Union[str, MergeSpec], nodes: Dict[str, _Node]
    ) -> str:
        if isinstance(inp, MergeSpec):
            sid = nodes[inp.spec_id].sid
            if sid is None:
                raise RuntimeError(
                    f"child spec {inp.spec_id} not yet executed (cycle?)"
                )
            return sid
        return inp

    def _node_alive(self, node: _Node, interested: Dict[int, List[JobHandle]]) -> bool:
        handles = interested.get(id(node), [])
        return any(
            h.status not in JobState.TERMINAL and not h.cancel_requested
            for h in handles
        )

    def _run_level(
        self,
        level_nodes: List[_Node],
        nodes: Dict[str, _Node],
        opts: WindowOptions,
        interested: Dict[int, List[JobHandle]],
        dead: Dict[int, BaseException],
    ) -> Dict:
        # deterministic order: by spec content digest, then requested sid
        # (identical specs executing under distinct names)
        level_nodes = sorted(
            level_nodes, key=lambda n: (n.spec.spec_id, n.sid_hint or "")
        )

        # drop nodes nobody wants anymore: every interested job already
        # terminal or cancel-requested (queued-cancel), or an input died
        live_nodes: List[_Node] = []
        for node in level_nodes:
            handles_n = interested.get(id(node), [])
            if handles_n and all(
                h.status == JobState.QUEUED for h in handles_n
            ):
                # every consumer was requeued (transient crash earlier in
                # this window) — skip quietly; a later attempt re-runs it
                continue
            dead_child = next(
                (
                    c for c in node.spec.children()
                    if id(nodes[c.spec_id]) in dead
                ),
                None,
            )
            if dead_child is not None:
                err = dead[id(nodes[dead_child.spec_id])]
                dead[id(node)] = err
                for h in interested.get(id(node), []):
                    if h.status not in JobState.TERMINAL:
                        self._fail_handle(h, err)
                continue
            if not self._node_alive(node, interested):
                err = MergeCancelled(
                    f"merge {node.sid_hint or node.spec.spec_id} cancelled "
                    f"before execution"
                )
                dead[id(node)] = err
                for h in interested.get(id(node), []):
                    if h.status not in JobState.TERMINAL:
                        self._fail_handle(h, err)
                continue
            live_nodes.append(node)
        level_nodes = live_nodes
        if not level_nodes:
            return {}

        pool_spec = (
            BudgetSpec.parse(opts.shared_budget)
            if opts.shared_budget is not None else None
        )
        pool_is_fraction = pool_spec is not None and pool_spec.kind == "fraction"

        resolved: List[Dict[str, Any]] = []
        for node in level_nodes:
            spec = node.spec
            base_id = self._resolve_input(spec.base, nodes)
            expert_ids = [self._resolve_input(e, nodes) for e in spec.experts]
            if opts.analyze:
                self.ensure_analyzed(base_id, expert_ids)
            resolved.append({"base_id": base_id, "expert_ids": expert_ids})

        # -- packed physical layout (auto-prefer / forced) -----------------
        # one layout per level: it must cover every expert the level reads
        # so the shared readers and the planner cost the same bytes.
        level_experts = sorted({e for r in resolved for e in r["expert_ids"]})
        layout_id = self._select_layout(
            opts.prefer_packed, level_experts, [r["base_id"] for r in resolved]
        )

        # arbitration group per node: the sorted set of tenants whose jobs
        # consume it.  A deduped node shared across tenants is capped by
        # their combined remaining shares and billed to them in equal
        # parts — never in full to whichever handle sorted first.
        node_tenants: Dict[int, Tuple[str, ...]] = {
            id(n): tuple(sorted({
                h.tenant for h in interested[id(n)]
            }))
            for n in level_nodes
        }
        batch_jobs: List[BatchJob] = []
        for node, res in zip(level_nodes, resolved):
            spec = node.spec
            base_id = res["base_id"]
            expert_ids = res["expert_ids"]
            # merge-graph lineage: any input that is itself a committed
            # merge snapshot becomes a DAG edge of this node.
            parent_sids = [
                i
                for i in [base_id, *expert_ids]
                if self.catalog.get_manifest(i) is not None
            ]
            self.catalog.record_spec(
                spec.spec_id, spec.name, spec.op, spec.to_dict()
            )
            naive = None
            if spec.budget.kind == "fraction":
                naive = cost_model.naive_expert_cost(self.catalog, expert_ids)
            budget_b = spec.budget.resolve(naive)
            batch_jobs.append(
                BatchJob(
                    base_id=base_id,
                    expert_ids=expert_ids,
                    op=spec.op,
                    theta=spec.theta,
                    budget_b=budget_b,
                    conflict_aware=spec.conflict_aware,
                    reuse=spec.reuse_plan,
                    spec_id=spec.spec_id,
                    parent_sids=parent_sids,
                    layout_id=layout_id,
                    group="\x1f".join(node_tenants[id(node)]),
                )
            )

        pool_b = None
        if pool_spec is not None:
            # The pool caps the level's UNION read schedule, so a
            # fractional pool resolves against the naive cost of the
            # level's distinct expert set — not the per-job sum.
            naive_union = None
            if pool_is_fraction:
                distinct = sorted({e for r in resolved for e in r["expert_ids"]})
                naive_union = cost_model.naive_expert_cost(self.catalog, distinct)
            pool_b = pool_spec.resolve(naive_union)
        # the service's global budget pool caps the same union; whatever
        # earlier windows left unspent carries over automatically
        group_budgets: Optional[Dict[str, Optional[int]]] = None
        if self.arbiter.enabled:
            arb_remaining = self.arbiter.global_remaining()
            pool_b = (
                arb_remaining if pool_b is None else min(pool_b, arb_remaining)
            )
            # a tenant's remaining share is granted ONCE per level: when
            # it appears in several groups (own nodes + deduped shared
            # nodes), the share is divided across them.  A shared group's
            # cap is n·min(member grants): its union is billed in equal
            # parts, so each member's bill union/n stays within its own
            # grant — a generous co-tenant can never subsidize a tenant
            # past its weighted-fair share.
            groups = set(node_tenants.values())
            appearances: Dict[str, int] = {}
            for tenants in groups:
                for t in tenants:
                    appearances[t] = appearances.get(t, 0) + 1
            group_budgets = {}
            for tenants in groups:
                grants = [
                    self.arbiter.remaining(t) // appearances[t]
                    for t in tenants
                ]
                group_budgets["\x1f".join(tenants)] = (
                    len(tenants) * min(grants)
                )

        # tier-aware billing: when any expert of this level is served from
        # a remote object store, bill candidates by the tier that would
        # serve them now (RAM free / disk cheap / remote full) so a fixed
        # budget admits more blocks as the shared warm tiers fill up
        tier_probe = None
        if opts.tier_billing and any(
            self.snapshots.models.is_remote(e) for e in level_experts
        ):
            from repro.store.tiered import make_tier_probe

            ram_readers = {
                m: r
                for (lid, m), r in self._readers.items()
                if lid is None and m in level_experts
            }
            tier_probe = make_tier_probe(
                self.snapshots.models,
                self.block_size,
                ram_readers=ram_readers,
            )

        bp = plan_batch(
            self.catalog,
            batch_jobs,
            block_size=self.block_size,
            shared_budget_b=pool_b,
            group_budgets=group_budgets,
            tier_probe=tier_probe,
        )
        # weighted-fair accounting: each tenant group is charged the
        # physical union of its own nodes' selections (what a shared-read
        # window pays on its behalf), split equally when a deduped node
        # serves several tenants; the global pool is charged the window
        # union once.  Realized I/O never exceeds planned (§5.1), so
        # charging the plan keeps the pool sound.
        for g, ub in bp.stats.get("group_union_bytes", {}).items():
            tenants = g.split("\x1f")
            each = ub // len(tenants)
            for i, t in enumerate(tenants):
                self.arbiter.charge(
                    t, ub - each * (len(tenants) - 1) if i == 0 else each
                )
        self.arbiter.charge_global(bp.stats.get("c_expert_hat_union", 0))

        # -- shared expert readers: one open (cached) reader per model ----
        expert_readers = None
        cache_readers: Dict[str, CachingModelReader] = {}
        owned_readers: Dict[str, CachingModelReader] = {}
        owned_layout = None
        cache_before = (0, 0, 0)
        sharded = getattr(opts, "execution", "local") == "sharded"
        if sharded:
            # workers open their own readers in their own processes —
            # coordinator-side shared readers would never see a byte
            pass
        elif self.persistent_cache and opts.shared_reads:
            cache_readers = self._shared_readers(layout_id, level_experts)
            expert_readers = cache_readers
            cache_before = self._cache_counters(cache_readers)
        elif opts.shared_reads and len(level_nodes) > 1:
            # one byte budget for the whole level: the cap bounds the
            # combined footprint across all expert readers
            cache_budget = CacheBudget(opts.cache_max_bytes)
            if layout_id is not None:
                # cross-job sharing composes with the packed layout: one
                # opened layout dedups extents across jobs, and the block
                # cache fans decoded blocks out to later jobs
                owned_layout = self.snapshots.packed.open_layout(layout_id)
                open_one = owned_layout.open_member
            else:
                open_one = self.snapshots.models.open_model
            cache_readers = owned_readers = {
                e: CachingModelReader(
                    open_one(e), budget=cache_budget, stats=self.stats
                )
                for e in level_experts
            }
            expert_readers = cache_readers

        try:
            for node, pr in zip(level_nodes, bp.results):
                handles = interested.get(id(node), [])
                cancel = _NodeCancel(handles) if handles else None
                # pin the executing sid before any I/O: a crash mid-merge
                # (or a service restart) can only find the progress
                # journal again if the snapshot id is stable and recorded
                # on the job rows, so generate it here instead of letting
                # the executor pick one
                exec_sid = node.sid_hint or TransactionManager.new_sid()
                if node.sid_hint is None and handles:
                    self.catalog.update_jobs(
                        [(h.job_id, {"sid": exec_sid}) for h in handles]
                    )
                plan = pr.plan
                resume = self._resume_states.pop(exec_sid, None)
                if resume is not None:
                    # re-planning under today's arbitration could shift
                    # the block selection and invalidate the journal:
                    # replay the dead attempt's exact plan from the
                    # catalog so digests line up and the journaled prefix
                    # stays bit-compatible
                    orig = self.catalog.get_plan(resume.plan_id)
                    if orig is not None:
                        plan = MergePlan.from_payload(orig["payload"])
                    if resume.plan_digest != plan.digest():
                        resume.discard()
                        resume = None
                try:
                    if sharded:
                        # scatter this node across shard workers; the
                        # coordinator mirrors execute_merge's txn
                        # semantics so every handler below works as-is
                        from repro.dist.coordinator import run_sharded_merge
                        from repro.dist.lease import DistOptions

                        result = run_sharded_merge(
                            plan,
                            self.snapshots,
                            self.catalog,
                            sid=exec_sid,
                            txn=self.txn,
                            options=getattr(opts, "dist", None)
                            or DistOptions(),
                            coalesce=opts.coalesce,
                            verify=getattr(opts, "verify", True),
                            pipeline=opts.pipeline,
                            cancel=cancel,
                            progress=self._node_progress(handles),
                            resume=resume,
                        )
                    else:
                        result = execute_merge(
                            plan,
                            self.snapshots,
                            self.catalog,
                            sid=exec_sid,
                            txn=self.txn,
                            compute=opts.compute,
                            coalesce=opts.coalesce,
                            verify=getattr(opts, "verify", True),
                            expert_readers=expert_readers,
                            pipeline=opts.pipeline,
                            cancel=cancel,
                            progress=self._node_progress(handles),
                            resume=resume,
                        )
                except MergeCancelled as e:
                    dead[id(node)] = e
                    for h in handles:
                        if h.status not in JobState.TERMINAL:
                            self._fail_handle(h, e)
                    continue
                except SimulatedCrash as e:
                    # in-process worker death: the transaction was NOT
                    # aborted, so staging and the progress journal
                    # survive — salvage the validated prefix and requeue
                    # the survivors with backoff (the scheduler thread
                    # must outlive the crash: only this node dies)
                    self.txn.forsake()
                    state = self.txn.prepare_resume(exec_sid)
                    if state is not None:
                        self._resume_states[exec_sid] = state
                    self._requeue_or_quarantine(node, handles, e, dead)
                    continue
                # broad-except-ok: per-node containment — MergeCancelled
                # and SimulatedCrash are taken by the dedicated handlers
                # above; everything else either requeues (transient) or
                # settles the node's handles with the error
                except Exception as e:
                    if is_transient(e):
                        # transient I/O failure (timeouts, dropped
                        # connections): the executor already aborted, but
                        # a journal left by an earlier forsaken attempt
                        # may still be salvageable
                        state = self.txn.prepare_resume(exec_sid)
                        if state is not None:
                            self._resume_states[exec_sid] = state
                        self._requeue_or_quarantine(node, handles, e, dead)
                        continue
                    dead[id(node)] = e
                    for h in handles:
                        if h.status not in JobState.TERMINAL:
                            self._fail_handle(h, e)
                    continue
                if resume is not None:
                    # budget soundness across attempts: the dead attempt
                    # already paid for the journaled prefix, and this
                    # window's plan_batch charge re-billed the full union
                    # — refund the overlap so each expert byte is charged
                    # exactly once per committed merge
                    refund = resume.journaled_expert_bytes(plan)
                    if refund > 0:
                        tenants = node_tenants.get(id(node), ())
                        if tenants:
                            each = refund // len(tenants)
                            for i, t in enumerate(tenants):
                                self.arbiter.refund(
                                    t,
                                    refund - each * (len(tenants) - 1)
                                    if i == 0 else each,
                                )
                        self.arbiter.refund_global(refund)
                    result.stats["resumed"] = True
                result.stats["plan"] = pr.stats
                node.sid = result.sid
                node.result = result
        finally:
            for r in owned_readers.values():
                r.close()
            if owned_layout is not None:
                owned_layout.close()

        stats = dict(bp.stats)
        stats["layout_id"] = layout_id
        if cache_readers:
            hits, misses, saved = self._cache_counters(cache_readers)
            stats["cache"] = {
                "hits": hits - cache_before[0],
                "misses": misses - cache_before[1],
                "bytes_saved": saved - cache_before[2],
            }
        if len(level_nodes) > 1:
            for node in level_nodes:
                if node.result is not None:
                    node.result.stats["batch"] = stats
        return stats

    @staticmethod
    def _cache_counters(
        readers: Dict[str, CachingModelReader]
    ) -> Tuple[int, int, int]:
        return (
            sum(r.hits for r in readers.values()),
            sum(r.misses for r in readers.values()),
            sum(r.bytes_saved for r in readers.values()),
        )

    def _node_progress(self, handles: List[JobHandle]):
        if not handles:
            return None

        def cb(done: int, total: int) -> None:
            for h in handles:
                h._update_progress(done, total)

        return cb

    # ------------------------------------------------- persistent readers
    def _shared_readers(
        self, layout_id: Optional[str], model_ids: List[str]
    ) -> Dict[str, CachingModelReader]:
        """Service-lifetime cached readers: later windows re-use blocks
        already scanned for earlier (in-flight or finished) work, so an
        expert shared across windows is still read once physically while
        the shared CacheBudget has room.

        The open-reader set is LRU-bounded at ``max_open_readers`` so an
        always-on service over a large model fleet never accumulates
        file descriptors; the current level's readers are pinned against
        eviction.  (A reader pins its file, so re-registering a model id
        with different content mid-service is served from the old bytes
        until its reader is evicted — re-register under fresh ids.)"""
        pinned = {(layout_id, m) for m in model_ids}
        out: Dict[str, CachingModelReader] = {}
        for model_id in model_ids:
            key = (layout_id, model_id)
            reader = self._readers.pop(key, None)
            if reader is None:
                if layout_id is not None:
                    layout = self._layouts.get(layout_id)
                    if layout is None:
                        layout = self._layouts[layout_id] = (
                            self.snapshots.packed.open_layout(layout_id)
                        )
                    inner = layout.open_member(model_id)
                else:
                    inner = self.snapshots.models.open_model(model_id)
                reader = CachingModelReader(
                    inner, budget=self._cache_budget, stats=self.stats
                )
            self._readers[key] = reader  # re-insert = most recently used
            out[model_id] = reader
        while len(self._readers) > self.max_open_readers:
            victim = next(
                (k for k in self._readers if k not in pinned), None
            )
            if victim is None:
                break  # everything open is pinned by this level
            self._readers.pop(victim).close()
        return out

    # ---------------------------------------------------------------- packed
    def _select_layout(
        self,
        prefer_packed: Union[bool, str],
        expert_ids: List[str],
        base_ids: List[str],
    ) -> Optional[str]:
        """Resolve the packed layout one execution level reads from.

        A layout is only *applicable* when every expert of the level is a
        member AND the level's (single) base is the layout's own base —
        elision means "delta vs the layout's base is zero", so any other
        base would make synthesized zero deltas wrong.  Inapplicable
        levels fall back to flat reads: in a merge graph, upper levels
        whose inputs are freshly-committed snapshots are never members of
        a pre-built layout, and a forced layout must not abort the graph
        mid-way (unknown ids and block-size mismatches still raise — they
        are configuration errors, not graph structure).
        """
        if not prefer_packed or not expert_ids:
            return None
        bases = set(base_ids)
        if isinstance(prefer_packed, str):
            layout = self.catalog.get_packed_layout(prefer_packed)
            if layout is None:
                raise KeyError(f"packed layout {prefer_packed!r} not found")
            if layout["block_size"] != self.block_size:
                raise ValueError(
                    f"layout {prefer_packed!r} is packed at block_size="
                    f"{layout['block_size']}, session uses {self.block_size}"
                )
            members = set(self.catalog.packed_layout_members(prefer_packed))
            applicable = (
                bases == {layout["base_id"]}
                and all(e in members for e in expert_ids)
            )
            if not applicable:
                # fall back, but never silently: on a plain single-level
                # merge this usually means a misconfigured --layout
                causes = []
                if bases != {layout["base_id"]}:
                    causes.append(
                        f"layout base {layout['base_id']!r} vs merge "
                        f"base(s) {sorted(bases)}"
                    )
                non_members = [e for e in expert_ids if e not in members]
                if non_members:
                    causes.append(f"non-members: {non_members}")
                warnings.warn(
                    f"forced packed layout {prefer_packed!r} does not apply "
                    f"to this level ({'; '.join(causes)}) — reading flat "
                    f"checkpoints instead",
                    stacklevel=3,
                )
                return None
            return prefer_packed
        # auto-prefer: only lossless layouts packed against this exact
        # base qualify (outputs must stay bit-identical to the flat
        # store; lossy layouts are an explicit opt-in by id)
        if len(bases) != 1:
            return None
        return self.catalog.find_packed_layout(
            expert_ids, self.block_size, lossless_only=True,
            base_id=bases.pop(),
        )

    # ------------------------------------------------------- substrate ops
    def jobs(self, state: Optional[str] = None,
             tenant: Optional[str] = None) -> List[Dict]:
        """Job table view (catalog-backed; survives restarts)."""
        return self.catalog.list_jobs(state=state, tenant=tenant)

    # ------------------------------------------------------- mergefsck scrub
    def _maybe_scrub(self) -> None:
        """Scheduler-thread hook: run a repairing fsck pass when the
        service has been idle for ``scrub_idle_s``.  Disabled (None) by
        default; scrub failures never take down the scheduler."""
        if self.scrub_idle_s is None:
            return
        now = time.monotonic()
        if now - self._last_scrub < self.scrub_idle_s:
            return
        self._last_scrub = now
        try:
            self.scrub(repair=True)
        # broad-except-ok: the scrubber is best-effort background
        # hygiene; a failed pass is reported via status(), not by
        # killing the scheduler thread
        except Exception as e:
            with self._cond:
                self._scrub_report = {"error": str(e)}

    def scrub(self, repair: bool = True) -> Dict[str, Any]:
        """Run mergefsck over the workspace now (also available as
        ``merge_cli fsck``): re-hashes snapshots, packed extents, and
        disk-cache extents against their cataloged contracts, repairing
        or quarantining what it can (see :mod:`repro.store.fsck`).  The
        latest report is kept and surfaced in :meth:`status`."""
        report = self.fsck(repair=repair, rate_mbps=self.scrub_rate_mbps)
        doc = report.to_dict()
        with self._cond:
            self._scrub_report = doc
        return doc

    def status(self) -> Dict[str, Any]:
        """Service health snapshot: in-memory job-state counts, pending
        queue depth, budget-pool usage, sids holding a validated resume
        state (crashed work awaiting its next attempt), quarantined job
        ids (catalog-backed, so restarts are included), and the latest
        background-scrub report (None until a scrub has run)."""
        with self._cond:
            jobs = list(self._jobs.values())
            pending = len(self._pending)
            scrub_report = self._scrub_report
        counts: Dict[str, int] = {}
        for j in jobs:
            s = j.handle.status
            counts[s] = counts.get(s, 0) + 1
        return {
            "jobs": counts,
            "pending": pending,
            "windows_run": self._window_seq,
            "budget": self.arbiter.usage(),
            "resumable_sids": sorted(self._resume_states),
            "quarantined": [
                r["job_id"]
                for r in self.catalog.list_jobs(state=JobState.QUARANTINED)
            ],
            "scrub": scrub_report,
        }
