"""MergeSpec — declarative, composable merge graphs (API v2).

An :class:`OperatorSpec` is a merge operator name plus a θ dict validated
against the operator registry's per-operator schema (unknown keys and
out-of-range values fail at *spec construction*, not mid-execution).

A :class:`MergeSpec` is one merge node: base, experts, operator, typed
budget.  Crucially, ``base`` and any expert may be **another MergeSpec**,
which makes specs first-class merge *graphs* — e.g. TIES over two DARE
sub-merges — planned and executed as a DAG with per-node lineage:

    sub = MergeSpec.build("base", ["e1", "e2"], op="dare",
                          theta={"density": 0.5, "seed": 1}, name="sub")
    top = MergeSpec.build("base", [sub, "e0"], op="ties",
                          theta={"trim_frac": 0.2}, budget="30%")

Specs serialize to plain JSON/YAML-able dicts (``to_dict``/``from_dict``)
so merge graphs can live in version control and be submitted via the
CLI.  ``spec_id`` is a content digest: structurally identical sub-graphs
dedupe to a single execution inside a batch session.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterator, List, Optional, Union

from repro.api.budget import BudgetLike, BudgetSpec
from repro.core import operators as ops

Input = Union[str, "MergeSpec"]


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """Validated merge operator reference: ``op`` + schema-checked θ."""

    op: str
    theta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    strict: bool = True

    def __post_init__(self):
        op = self.op.lower()
        object.__setattr__(self, "op", op)
        ops.get_operator(op)  # raises on unknown operator
        object.__setattr__(
            self, "theta", ops.validate_theta(op, self.theta, strict=self.strict)
        )

    def to_dict(self) -> Dict[str, Any]:
        return {"op": self.op, "theta": dict(self.theta)}

    @classmethod
    def from_dict(cls, doc: Dict[str, Any]) -> "OperatorSpec":
        return cls(doc["op"], dict(doc.get("theta") or {}))


@dataclasses.dataclass
class MergeSpec:
    """One node of a declarative merge graph."""

    base: Input
    experts: List[Input]
    operator: OperatorSpec
    budget: BudgetSpec = dataclasses.field(default_factory=BudgetSpec.unbounded)
    name: Optional[str] = None
    conflict_aware: bool = True
    reuse_plan: bool = True

    def __post_init__(self):
        if not self.experts:
            raise ValueError("MergeSpec needs at least one expert input")
        for inp in [self.base, *self.experts]:
            if not isinstance(inp, (str, MergeSpec)):
                raise TypeError(
                    f"merge input must be a model id or MergeSpec, got "
                    f"{type(inp).__name__}"
                )

    # ------------------------------------------------------------- builders
    @classmethod
    def build(
        cls,
        base: Input,
        experts: List[Input],
        op: str = "ties",
        theta: Optional[Dict[str, Any]] = None,
        budget: BudgetLike = None,
        name: Optional[str] = None,
        conflict_aware: bool = True,
        reuse_plan: bool = True,
    ) -> "MergeSpec":
        """Convenience constructor with loose inputs (parses the budget)."""
        return cls(
            base=base,
            experts=list(experts),
            operator=OperatorSpec(op, dict(theta or {})),
            budget=BudgetSpec.parse(budget),
            name=name,
            conflict_aware=conflict_aware,
            reuse_plan=reuse_plan,
        )

    # -------------------------------------------------------------- queries
    @property
    def op(self) -> str:
        return self.operator.op

    @property
    def theta(self) -> Dict[str, Any]:
        return dict(self.operator.theta)

    def children(self) -> List["MergeSpec"]:
        """Nested sub-merges among this node's inputs (base first)."""
        return [i for i in [self.base, *self.experts] if isinstance(i, MergeSpec)]

    def walk(self) -> Iterator["MergeSpec"]:
        """Post-order traversal of the spec DAG (children before parents),
        deduplicated by spec_id."""
        seen: Dict[str, bool] = {}

        def _walk(node: "MergeSpec") -> Iterator["MergeSpec"]:
            for child in node.children():
                yield from _walk(child)
            sid = node.spec_id
            if sid not in seen:
                seen[sid] = True
                yield node

        yield from _walk(self)

    def depth(self) -> int:
        """0 for leaf merges (all inputs are model ids)."""
        kids = self.children()
        return 0 if not kids else 1 + max(k.depth() for k in kids)

    # -------------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        def enc(inp: Input):
            return inp.to_dict() if isinstance(inp, MergeSpec) else inp

        doc: Dict[str, Any] = {
            "base": enc(self.base),
            "experts": [enc(e) for e in self.experts],
            "op": self.operator.op,
            "theta": dict(self.operator.theta),
            "budget": self.budget.to_json(),
        }
        if self.name:
            doc["name"] = self.name
        if not self.conflict_aware:
            doc["conflict_aware"] = False
        if not self.reuse_plan:
            doc["reuse_plan"] = False
        return doc

    @classmethod
    def from_dict(cls, doc: Dict[str, Any]) -> "MergeSpec":
        def dec(inp) -> Input:
            if isinstance(inp, str):
                return inp
            if isinstance(inp, dict):
                return cls.from_dict(inp)
            raise TypeError(f"bad merge input in spec document: {inp!r}")

        return cls.build(
            base=dec(doc["base"]),
            experts=[dec(e) for e in doc.get("experts") or []],
            op=doc.get("op", "ties"),
            theta=doc.get("theta"),
            budget=doc.get("budget"),
            name=doc.get("name"),
            conflict_aware=bool(doc.get("conflict_aware", True)),
            reuse_plan=bool(doc.get("reuse_plan", True)),
        )

    def canonical(self) -> str:
        """Canonical JSON for content addressing — nested specs collapse
        to their spec_id so structurally equal graphs share digests.
        ``name`` is part of the identity: it names a distinct output
        snapshot, so same-content-different-name specs execute separately."""

        def enc(inp: Input):
            return {"spec": inp.spec_id} if isinstance(inp, MergeSpec) else inp

        return json.dumps(
            {
                "base": enc(self.base),
                "experts": [enc(e) for e in self.experts],
                "op": self.operator.op,
                "theta": self.operator.theta,
                "budget": self.budget.to_json(),
                "conflict_aware": self.conflict_aware,
                "name": self.name,
            },
            sort_keys=True,
        )

    @property
    def spec_id(self) -> str:
        digest = hashlib.blake2b(
            self.canonical().encode(), digest_size=8
        ).hexdigest()
        return f"spec-{digest}"

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"MergeSpec({self.spec_id}, op={self.op!r}, "
            f"base={self.base if isinstance(self.base, str) else self.base.spec_id!r}, "
            f"experts={len(self.experts)}, budget={self.budget})"
        )
