"""BudgetSpec — typed expert-read budgets (API v2).

The legacy ``budget`` argument was stringly/numerically ambiguous:
``budget=1`` meant *1 byte* while ``budget=1.0`` meant *100% of the
naive expert cost*.  :class:`BudgetSpec` makes the unit part of the
type:

    BudgetSpec.parse("30%")       -> fraction of the naive expert cost
    BudgetSpec.parse("2GiB")      -> absolute bytes (binary units)
    BudgetSpec.parse("500MB")     -> absolute bytes (decimal units)
    BudgetSpec.parse(123456)      -> absolute bytes
    BudgetSpec.parse(0.3)         -> fraction (floats must be in (0, 1])
    BudgetSpec.parse(None)        -> unbounded (faithful full read)

``resolve(naive_bytes)`` binds a fraction to a concrete byte cap at
planning time; bytes/unbounded budgets resolve without the naive cost.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Optional, Union

_UNIT_BYTES = {
    "b": 1,
    "kb": 10**3,
    "mb": 10**6,
    "gb": 10**9,
    "tb": 10**12,
    "kib": 2**10,
    "mib": 2**20,
    "gib": 2**30,
    "tib": 2**40,
}

_SIZE_RE = re.compile(
    r"^\s*(?P<num>\d+(?:\.\d+)?)\s*(?P<unit>[kmgt]i?b|b)?\s*$", re.IGNORECASE
)
_PCT_RE = re.compile(r"^\s*(?P<num>\d+(?:\.\d+)?)\s*%\s*$")

BudgetLike = Union[None, int, float, str, "BudgetSpec"]


@dataclasses.dataclass(frozen=True)
class BudgetSpec:
    """Expert-read budget with an explicit unit.

    ``kind`` is one of ``"unbounded"``, ``"bytes"``, ``"fraction"``.
    """

    kind: str
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in ("unbounded", "bytes", "fraction"):
            raise ValueError(f"unknown budget kind {self.kind!r}")
        if self.kind == "bytes" and (self.value < 0 or self.value != int(self.value)):
            raise ValueError(f"byte budget must be a non-negative int, got {self.value}")
        if self.kind == "fraction" and not (0 < self.value <= 1.0):
            raise ValueError(
                f"fraction budget must be in (0, 1], got {self.value}"
            )

    # ------------------------------------------------------------- builders
    @classmethod
    def unbounded(cls) -> "BudgetSpec":
        return cls("unbounded")

    @classmethod
    def bytes(cls, n: int) -> "BudgetSpec":
        return cls("bytes", int(n))

    @classmethod
    def fraction(cls, f: float) -> "BudgetSpec":
        return cls("fraction", float(f))

    @classmethod
    def parse(cls, value: BudgetLike) -> "BudgetSpec":
        """Parse any accepted budget notation into a typed spec."""
        if value is None:
            return cls.unbounded()
        if isinstance(value, BudgetSpec):
            return value
        if isinstance(value, bool):
            raise TypeError("budget cannot be a bool")
        if isinstance(value, int):
            return cls.bytes(value)
        if isinstance(value, float):
            if 0 < value <= 1.0:
                return cls.fraction(value)
            raise ValueError(
                f"float budget {value} is ambiguous; use a fraction in "
                f"(0, 1], a '%' string, or an explicit byte count/unit string"
            )
        if isinstance(value, str):
            s = value.strip().lower()
            if s in ("", "none", "unbounded", "full"):
                return cls.unbounded()
            m = _PCT_RE.match(s)
            if m:
                pct = float(m.group("num"))
                if not (0 < pct <= 100):
                    raise ValueError(f"percentage budget must be in (0, 100], got {value!r}")
                return cls.fraction(pct / 100.0)
            m = _SIZE_RE.match(s)
            if m:
                num = float(m.group("num"))
                unit = m.group("unit")
                if unit is None:
                    if num != int(num):
                        raise ValueError(
                            f"bare numeric string {value!r} is ambiguous; "
                            f"use '30%' for fractions or '123B'/'2GiB' for bytes"
                        )
                    return cls.bytes(int(num))
                return cls.bytes(int(num * _UNIT_BYTES[unit.lower()]))
            raise ValueError(f"unparseable budget {value!r}")
        raise TypeError(f"unsupported budget type {type(value).__name__}")

    @classmethod
    def from_legacy(cls, value: BudgetLike, warn: bool = True) -> "BudgetSpec":
        """Legacy ``MergePipe.merge(budget=...)`` semantics, with the
        int/float footgun surfaced: ``budget=1`` (int) means **1 byte**,
        not 100%."""
        if warn and isinstance(value, int) and not isinstance(value, bool) and value == 1:
            warnings.warn(
                "budget=1 (int) means ONE BYTE, not 100%; pass budget=1.0, "
                "'100%', or a BudgetSpec to request the full naive expert "
                "read budget",
                UserWarning,
                stacklevel=3,
            )
        if isinstance(value, float) and value > 1.0:
            # legacy resolve_budget truncated floats > 1 to bytes
            if warn:
                warnings.warn(
                    f"float budget {value} > 1 interpreted as bytes "
                    f"(legacy); use an int or a unit string like '2GiB'",
                    UserWarning,
                    stacklevel=3,
                )
            return cls.bytes(int(value))
        return cls.parse(value)

    # -------------------------------------------------------------- queries
    @property
    def is_unbounded(self) -> bool:
        return self.kind == "unbounded"

    def resolve(self, naive_bytes: Optional[int] = None) -> Optional[int]:
        """Concrete byte cap (None = unbounded).  Fractions need the
        naive full-read expert cost to bind against."""
        if self.kind == "unbounded":
            return None
        if self.kind == "bytes":
            return int(self.value)
        if naive_bytes is None:
            raise ValueError(
                "fraction budget needs naive_bytes (the full-read expert "
                "cost) to resolve"
            )
        return int(self.value * naive_bytes)

    # -------------------------------------------------------- serialization
    def to_json(self) -> Optional[str]:
        if self.kind == "unbounded":
            return None
        if self.kind == "fraction":
            return f"{self.value * 100:g}%"
        return f"{int(self.value)}B"

    def __str__(self) -> str:
        return self.to_json() or "unbounded"
