"""Session — batched multi-merge planning and execution (API v2).

A :class:`Session` owns one workspace (snapshot store + catalog +
transaction manager) and accepts declarative :class:`~repro.api.spec.MergeSpec`
jobs:

    sess = Session(workspace)
    sess.submit(spec_a)
    sess.submit(spec_b)
    results = sess.run_all()

``run_all`` plans the whole job set together (:func:`repro.core.planner.plan_batch`)
and executes it with a **cross-job read schedule**: every expert model is
opened once behind a :class:`~repro.store.blockcache.CachingModelReader`,
so one physical scan of each selected expert block feeds every job that
selected it.  A J-job sweep over the same K experts thus pays ``O(K)``
expert reads instead of the legacy one-shot path's ``O(K·J)``.

Merge *graphs* (specs whose inputs are themselves specs) execute as a
DAG in depth order; intermediate snapshots are analyzed and fed forward,
and every node records its spec and parent edges in the catalog so
``explain()``/``merge_graph()`` can reconstruct the full lineage.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.api.budget import BudgetLike, BudgetSpec
from repro.api.spec import MergeSpec
from repro.core import blocks as blk
from repro.core import cost as cost_model
from repro.core.catalog import Catalog
from repro.core.executor import MergeResult, PipelineConfig, execute_merge
from repro.core.lineage import explain as _explain
from repro.core.lineage import lineage_chain, merge_graph, verify_snapshot
from repro.core.planner import BatchJob, plan_batch
from repro.core.sketch import analyze_model
from repro.core.transactions import TransactionManager
from repro.store.blockcache import CacheBudget, CachingModelReader
from repro.store.iostats import GLOBAL_STATS, IOStats
from repro.store.snapshot import SnapshotStore
from repro.store.tensorstore import load_model_arrays


class JobHandle:
    """A submitted merge job: spec + (after run_all) its committed result."""

    def __init__(self, spec: MergeSpec, sid: Optional[str] = None):
        self.spec = spec
        self.requested_sid = sid
        self.sid: Optional[str] = None
        self.result: Optional[MergeResult] = None

    @property
    def done(self) -> bool:
        return self.result is not None

    def __repr__(self) -> str:  # pragma: no cover
        state = self.sid if self.done else "pending"
        return f"JobHandle({self.spec.spec_id}, {state})"


class _Node:
    """One DAG node scheduled for execution (deduped by spec_id)."""

    def __init__(self, spec: MergeSpec, sid_hint: Optional[str]):
        self.spec = spec
        self.sid_hint = sid_hint
        self.sid: Optional[str] = None
        self.result: Optional[MergeResult] = None


#: default bound on the shared-read block cache per run_all level; misses
#: beyond the cap stream uncached (sharing degrades, memory stays bounded)
DEFAULT_CACHE_MAX_BYTES = 1 << 30


class Session:
    """Workspace-scoped entry point for the declarative v2 API."""

    def __init__(
        self,
        workspace: str,
        block_size: int = blk.DEFAULT_BLOCK_SIZE,
        stats: Optional[IOStats] = None,
        recover: bool = True,
    ):
        self.workspace = workspace
        self.block_size = block_size
        self.stats = stats or GLOBAL_STATS
        os.makedirs(workspace, exist_ok=True)
        self.snapshots = SnapshotStore(workspace, self.stats)
        self.catalog = Catalog(os.path.join(workspace, "catalog.sqlite"), self.stats)
        # referential integrity: deleting a model that snapshots' lineage
        # or a packed layout still references needs an explicit force=True
        self.snapshots.models.add_delete_guard(self.catalog.model_references)
        self.txn = TransactionManager(self.snapshots, self.catalog)
        if recover:
            self.txn.recover()
        self._queue: List[JobHandle] = []

    @classmethod
    def _from_parts(
        cls,
        snapshots: SnapshotStore,
        catalog: Catalog,
        txn: TransactionManager,
        block_size: int,
        stats: IOStats,
    ) -> "Session":
        """Internal: wrap an existing substrate (legacy facade delegation)
        without re-opening stores or re-running recovery."""
        sess = cls.__new__(cls)
        sess.workspace = snapshots.workspace
        sess.block_size = block_size
        sess.stats = stats
        sess.snapshots = snapshots
        sess.catalog = catalog
        sess.txn = txn
        sess._queue = []
        return sess

    # ------------------------------------------------------------ ingestion
    def register_model(
        self,
        model_id: str,
        arrays: Mapping[str, np.ndarray],
        kind: str = "full",
        scale: float = 1.0,
        analyze: bool = False,
        base_id: Optional[str] = None,
    ) -> str:
        meta: Dict[str, Any] = {"kind": kind}
        if kind == "adapter":
            meta["scale"] = scale
        self.snapshots.models.write_model(model_id, arrays, meta=meta)
        if analyze:
            self.analyze(model_id, base_id=base_id)
        return model_id

    def analyze(
        self, model_id: str, base_id: Optional[str] = None, force: bool = False
    ) -> Dict:
        return analyze_model(
            self.catalog,
            self.snapshots.models,
            model_id,
            self.block_size,
            base_id=base_id,
            force=force,
        )

    def ensure_analyzed(self, base_id: str, expert_ids: Sequence[str]) -> None:
        self.analyze(base_id)
        for e in expert_ids:
            self.analyze(e, base_id=base_id)

    # ---------------------------------------------------------------- batch
    def submit(
        self, spec: Union[MergeSpec, Dict], sid: Optional[str] = None
    ) -> JobHandle:
        """Queue a merge job (spec object or its dict form) for run_all."""
        if isinstance(spec, dict):
            spec = MergeSpec.from_dict(spec)
        handle = JobHandle(spec, sid=sid)
        self._queue.append(handle)
        return handle

    def run_all(
        self,
        shared_reads: bool = True,
        shared_budget: BudgetLike = None,
        compute: str = "pipelined",
        coalesce: bool = True,
        analyze: bool = True,
        cache_max_bytes: Union[int, None, str] = "auto",
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
    ) -> List[MergeResult]:
        """Plan and execute every queued job, sharing expert block reads.

        ``shared_budget`` optionally pools the *union* expert-read bytes
        of each DAG level (see :func:`repro.core.planner.plan_batch`);
        fractions resolve against the naive cost of the level's distinct
        expert set.  ``cache_max_bytes`` bounds the per-level shared-read
        cache (``"auto"`` = 1 GiB, ``None`` = unbounded); blocks beyond
        the cap stream uncached, trading sharing for bounded memory.

        ``compute`` defaults to the overlapped ``"pipelined"`` engine
        (prefetch → windowed vectorized compute → write-behind,
        bit-identical to ``"stream"``; see docs/EXECUTION.md); ``pipeline``
        optionally tunes its window/queue-depth knobs.

        ``prefer_packed=True`` (default) plans and reads each level from
        the most recent **lossless** packed layout covering all of the
        level's experts, when one exists (see docs/STORAGE.md — elision,
        dedup and compression make the same budget buy strictly more
        selected blocks).  Pass a layout id to force a specific layout
        (including lossy ones — an explicit opt-in), or ``False`` to
        always read flat checkpoints.
        Returns results in submission order.
        """
        if cache_max_bytes == "auto":
            cache_max_bytes = DEFAULT_CACHE_MAX_BYTES
        jobs = list(self._queue)
        if not jobs:
            return []

        # -- 1. expand spec DAGs, dedupe shared subgraphs by content ------
        nodes: Dict[str, _Node] = {}
        alias_roots: List[_Node] = []
        handle_nodes: Dict[int, _Node] = {}
        for handle in jobs:
            for spec in handle.spec.walk():
                node = nodes.get(spec.spec_id)
                if node is None:
                    nodes[spec.spec_id] = node = _Node(spec, spec.name)
            root = nodes[handle.spec.spec_id]
            if handle.requested_sid:
                if root.sid_hint and root.sid_hint != handle.requested_sid:
                    # same content already claimed under another sid: the
                    # user asked for a distinct snapshot — execute again
                    # under its own name (children still dedupe).
                    root = _Node(handle.spec, handle.requested_sid)
                    alias_roots.append(root)
                else:
                    root.sid_hint = handle.requested_sid
            handle_nodes[id(handle)] = root

        # -- 2. validate target snapshot ids before any work --------------
        # (the queue is only consumed after the batch completes, so a
        # rejected or failed batch can be fixed and rerun without
        # resubmitting)
        all_nodes = [*nodes.values(), *alias_roots]
        claimed: Dict[str, _Node] = {}
        for node in all_nodes:
            hint = node.sid_hint
            if not hint:
                continue
            other = claimed.get(hint)
            if other is not None and other is not node:
                raise ValueError(
                    f"two different merge jobs target snapshot id {hint!r} "
                    f"(specs {other.spec.spec_id} and {node.spec.spec_id})"
                )
            claimed[hint] = node
            if self.snapshots.is_published(hint):
                # incremental composition: if the committed snapshot was
                # produced by this exact spec, adopt it instead of
                # re-executing (or failing) — graphs can be built up
                # across run_all calls.
                man = self.catalog.get_manifest(hint)
                plan = (
                    self.catalog.get_plan(man["plan_id"]) if man else None
                )
                committed_spec = (plan or {}).get("payload", {}).get("spec_id")
                if committed_spec == node.spec.spec_id:
                    node.sid = hint
                    # stats keep the executor's standard shape so legacy
                    # callers reading seconds/plan/etc. keep working
                    node.result = MergeResult(
                        hint, man,
                        {"seconds": 0.0, "c_expert_run": 0,
                         "c_expert_hat": (plan or {}).get("c_expert_hat", 0),
                         "realized_expert_blocks": 0,
                         "compute": compute, "coalesce": coalesce,
                         "reused_snapshot": True,
                         "plan": {"reused": True, "plan_seconds": 0.0}},
                    )
                    continue
                raise ValueError(
                    f"snapshot {hint!r} already published in this workspace "
                    f"by a different spec; pick a fresh sid/name"
                )

        # -- 3. execute level by level (children before parents) ----------
        by_level: Dict[int, List[_Node]] = {}
        for node in all_nodes:
            if node.result is None:  # adopted snapshots skip execution
                by_level.setdefault(node.spec.depth(), []).append(node)
        for level in sorted(by_level):
            self._run_level(
                by_level[level],
                nodes,
                shared_reads=shared_reads,
                shared_budget=shared_budget,
                compute=compute,
                coalesce=coalesce,
                analyze=analyze,
                cache_max_bytes=cache_max_bytes,
                pipeline=pipeline,
                prefer_packed=prefer_packed,
            )

        # -- 4. hand results back in submission order ---------------------
        # (the queue is consumed only now: a mid-batch execution failure
        # leaves every job queued for a retry, where completed named
        # nodes are adopted instead of re-executed)
        results: List[MergeResult] = []
        for handle in jobs:
            node = handle_nodes[id(handle)]
            handle.sid = node.sid
            handle.result = node.result
            results.append(node.result)
        self._queue = self._queue[len(jobs):]
        return results

    def _resolve_input(self, inp: Union[str, MergeSpec], nodes: Dict[str, _Node]) -> str:
        if isinstance(inp, MergeSpec):
            sid = nodes[inp.spec_id].sid
            if sid is None:
                raise RuntimeError(
                    f"child spec {inp.spec_id} not yet executed (cycle?)"
                )
            return sid
        return inp

    def _run_level(
        self,
        level_nodes: List[_Node],
        nodes: Dict[str, _Node],
        shared_reads: bool,
        shared_budget: BudgetLike,
        compute: str,
        coalesce: bool,
        analyze: bool,
        cache_max_bytes: Optional[int],
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
    ) -> Dict:
        # deterministic order: by spec content digest, then requested sid
        # (identical specs executing under distinct names)
        level_nodes = sorted(
            level_nodes, key=lambda n: (n.spec.spec_id, n.sid_hint or "")
        )

        pool_spec = (
            BudgetSpec.parse(shared_budget) if shared_budget is not None else None
        )
        pool_is_fraction = pool_spec is not None and pool_spec.kind == "fraction"

        resolved: List[Dict[str, Any]] = []
        for node in level_nodes:
            spec = node.spec
            base_id = self._resolve_input(spec.base, nodes)
            expert_ids = [self._resolve_input(e, nodes) for e in spec.experts]
            if analyze:
                self.ensure_analyzed(base_id, expert_ids)
            resolved.append({"base_id": base_id, "expert_ids": expert_ids})

        # -- packed physical layout (auto-prefer / forced) -----------------
        # one layout per level: it must cover every expert the level reads
        # so the shared readers and the planner cost the same bytes.
        level_experts = sorted({e for r in resolved for e in r["expert_ids"]})
        layout_id = self._select_layout(
            prefer_packed, level_experts, [r["base_id"] for r in resolved]
        )

        batch_jobs: List[BatchJob] = []
        for node, res in zip(level_nodes, resolved):
            spec = node.spec
            base_id = res["base_id"]
            expert_ids = res["expert_ids"]
            # merge-graph lineage: any input that is itself a committed
            # merge snapshot becomes a DAG edge of this node.
            parent_sids = [
                i
                for i in [base_id, *expert_ids]
                if self.catalog.get_manifest(i) is not None
            ]
            self.catalog.record_spec(
                spec.spec_id, spec.name, spec.op, spec.to_dict()
            )
            naive = None
            if spec.budget.kind == "fraction":
                naive = cost_model.naive_expert_cost(self.catalog, expert_ids)
            budget_b = spec.budget.resolve(naive)
            batch_jobs.append(
                BatchJob(
                    base_id=base_id,
                    expert_ids=expert_ids,
                    op=spec.op,
                    theta=spec.theta,
                    budget_b=budget_b,
                    conflict_aware=spec.conflict_aware,
                    reuse=spec.reuse_plan,
                    spec_id=spec.spec_id,
                    parent_sids=parent_sids,
                    layout_id=layout_id,
                )
            )

        pool_b = None
        if pool_spec is not None:
            # The pool caps the level's UNION read schedule, so a
            # fractional pool resolves against the naive cost of the
            # level's distinct expert set — not the per-job sum.
            naive_union = None
            if pool_is_fraction:
                distinct = sorted({e for r in resolved for e in r["expert_ids"]})
                naive_union = cost_model.naive_expert_cost(self.catalog, distinct)
            pool_b = pool_spec.resolve(naive_union)

        bp = plan_batch(
            self.catalog,
            batch_jobs,
            block_size=self.block_size,
            shared_budget_b=pool_b,
        )

        # -- shared expert readers: one open (cached) reader per model ----
        expert_readers = None
        cache_readers: Dict[str, CachingModelReader] = {}
        shared_layout = None
        if shared_reads and len(level_nodes) > 1:
            # one byte budget for the whole level: the cap bounds the
            # combined footprint across all expert readers
            cache_budget = CacheBudget(cache_max_bytes)
            if layout_id is not None:
                # cross-job sharing composes with the packed layout: one
                # opened layout dedups extents across jobs, and the block
                # cache fans decoded blocks out to later jobs
                shared_layout = self.snapshots.packed.open_layout(layout_id)
                open_one = shared_layout.open_member
            else:
                open_one = self.snapshots.models.open_model
            cache_readers = {
                e: CachingModelReader(open_one(e), budget=cache_budget)
                for e in level_experts
            }
            expert_readers = cache_readers

        try:
            for node, pr in zip(level_nodes, bp.results):
                result = execute_merge(
                    pr.plan,
                    self.snapshots,
                    self.catalog,
                    sid=node.sid_hint,
                    txn=self.txn,
                    compute=compute,
                    coalesce=coalesce,
                    expert_readers=expert_readers,
                    pipeline=pipeline,
                )
                result.stats["plan"] = pr.stats
                node.sid = result.sid
                node.result = result
        finally:
            for r in cache_readers.values():
                r.close()
            if shared_layout is not None:
                shared_layout.close()

        stats = dict(bp.stats)
        stats["layout_id"] = layout_id
        if cache_readers:
            stats["cache"] = {
                "hits": sum(r.hits for r in cache_readers.values()),
                "misses": sum(r.misses for r in cache_readers.values()),
                "bytes_saved": sum(
                    r.bytes_saved for r in cache_readers.values()
                ),
            }
        if len(level_nodes) > 1:
            for node in level_nodes:
                node.result.stats["batch"] = stats
        return stats

    # ---------------------------------------------------------------- packed
    def _select_layout(
        self,
        prefer_packed: Union[bool, str],
        expert_ids: List[str],
        base_ids: List[str],
    ) -> Optional[str]:
        """Resolve the packed layout one execution level reads from.

        A layout is only *applicable* when every expert of the level is a
        member AND the level's (single) base is the layout's own base —
        elision means "delta vs the layout's base is zero", so any other
        base would make synthesized zero deltas wrong.  Inapplicable
        levels fall back to flat reads: in a merge graph, upper levels
        whose inputs are freshly-committed snapshots are never members of
        a pre-built layout, and a forced layout must not abort the graph
        mid-way (unknown ids and block-size mismatches still raise — they
        are configuration errors, not graph structure).
        """
        if not prefer_packed or not expert_ids:
            return None
        bases = set(base_ids)
        if isinstance(prefer_packed, str):
            layout = self.catalog.get_packed_layout(prefer_packed)
            if layout is None:
                raise KeyError(f"packed layout {prefer_packed!r} not found")
            if layout["block_size"] != self.block_size:
                raise ValueError(
                    f"layout {prefer_packed!r} is packed at block_size="
                    f"{layout['block_size']}, session uses {self.block_size}"
                )
            members = set(self.catalog.packed_layout_members(prefer_packed))
            applicable = (
                bases == {layout["base_id"]}
                and all(e in members for e in expert_ids)
            )
            if not applicable:
                # fall back, but never silently: on a plain single-level
                # merge this usually means a misconfigured --layout
                import warnings

                causes = []
                if bases != {layout["base_id"]}:
                    causes.append(
                        f"layout base {layout['base_id']!r} vs merge "
                        f"base(s) {sorted(bases)}"
                    )
                non_members = [e for e in expert_ids if e not in members]
                if non_members:
                    causes.append(f"non-members: {non_members}")
                warnings.warn(
                    f"forced packed layout {prefer_packed!r} does not apply "
                    f"to this level ({'; '.join(causes)}) — reading flat "
                    f"checkpoints instead",
                    stacklevel=3,
                )
                return None
            return prefer_packed
        # auto-prefer: only lossless layouts packed against this exact
        # base qualify (outputs must stay bit-identical to the flat
        # store; lossy layouts are an explicit opt-in by id)
        if len(bases) != 1:
            return None
        return self.catalog.find_packed_layout(
            expert_ids, self.block_size, lossless_only=True,
            base_id=bases.pop(),
        )

    def repack(
        self,
        model_ids: Sequence[str],
        base_id: str,
        layout_id: Optional[str] = None,
        options: Optional["Any"] = None,
    ) -> Dict:
        """Rewrite checkpoints into a content-addressed packed layout
        (store/packed): cross-model dedup, zero-delta elision, optional
        downcast/compression.  Returns the repack report; subsequent
        ``run``/``run_all`` calls auto-prefer the layout when lossless.
        """
        return self.snapshots.packed.repack(
            base_id,
            list(model_ids),
            self.block_size,
            layout_id=layout_id,
            options=options,
            catalog=self.catalog,
        )

    def list_layouts(self) -> List[str]:
        return self.catalog.list_packed_layouts()

    # ------------------------------------------------------------- one-shot
    def run(
        self,
        spec: Union[MergeSpec, Dict],
        sid: Optional[str] = None,
        compute: str = "pipelined",
        coalesce: bool = True,
        analyze: bool = True,
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
    ) -> MergeResult:
        """Submit one spec (possibly a whole merge graph) and execute it."""
        handle = self.submit(spec, sid=sid)
        self.run_all(
            shared_reads=True, compute=compute, coalesce=coalesce,
            analyze=analyze, pipeline=pipeline, prefer_packed=prefer_packed,
        )
        assert handle.result is not None
        return handle.result

    # ---------------------------------------------------------------- audit
    def explain(self, sid: str) -> Dict:
        return _explain(self.catalog, self.snapshots, sid)

    def merge_graph(self, sid: str) -> Dict:
        return merge_graph(self.catalog, sid)

    def lineage(self, sid: str):
        return lineage_chain(self.catalog, sid)

    def verify(self, sid: str) -> bool:
        return verify_snapshot(self.snapshots, sid)

    # ----------------------------------------------------------------- data
    def load(self, model_id: str) -> Dict[str, np.ndarray]:
        return load_model_arrays(self.snapshots.models, model_id)

    def list_snapshots(self):
        return self.snapshots.list_snapshots()

    def close(self) -> None:
        self.catalog.close()
