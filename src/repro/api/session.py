"""Session — batched multi-merge planning and execution (API v2).

A :class:`Session` owns one workspace (snapshot store + catalog +
transaction manager) and accepts declarative :class:`~repro.api.spec.MergeSpec`
jobs:

    with Session(workspace) as sess:
        sess.submit(spec_a)
        sess.submit(spec_b)
        results = sess.run_all()

``run_all`` is a compatibility wrapper over the asynchronous
:class:`~repro.api.service.MergeService`: the queued jobs are submitted
to an embedded (inline, unthreaded) service as one atomic scheduling
window and waited on — golden-tested bit-identical, with identical
per-category IOStats, to the former blocking batch barrier.  The window
plans the whole job set together (:func:`repro.core.planner.plan_batch`)
and executes it with a **cross-job read schedule**: every expert model is
opened once behind a :class:`~repro.store.blockcache.CachingModelReader`,
so one physical scan of each selected expert block feeds every job that
selected it.  A J-job sweep over the same K experts thus pays ``O(K)``
expert reads instead of the legacy one-shot path's ``O(K·J)``.

Merge *graphs* (specs whose inputs are themselves specs) execute as a
DAG in depth order; intermediate snapshots are analyzed and fed forward,
and every node records its spec and parent edges in the catalog so
``explain()``/``merge_graph()`` can reconstruct the full lineage.

For an always-on service surface — concurrent tenants, admission
control, budget arbitration, cancellation — construct a
:class:`~repro.api.service.MergeService` directly (docs/SERVICE.md).

I/O accounting is session-scoped: a Session (or MergeService) built
without an explicit ``stats`` gets its **own** :class:`IOStats`, so two
concurrent sessions never cross-pollute counters.  Pass
``stats=GLOBAL_STATS`` to opt into the legacy process-global instance.
"""
from __future__ import annotations

import os
import uuid
from typing import Any, Dict, List, Optional, Sequence, Union

from repro.api.budget import BudgetLike
from repro.api.jobs import JobHandle, JobState
from repro.api.service import MergeService, WindowOptions
from repro.api.spec import MergeSpec
from repro.api.workspace import WorkspaceOps
from repro.core import blocks as blk
from repro.core.catalog import Catalog
from repro.core.executor import MergeResult, PipelineConfig
from repro.core.transactions import TransactionManager
from repro.store.iostats import IOStats
from repro.store.snapshot import SnapshotStore

#: re-exported for backward compatibility (the bound moved to service.py)
from repro.api.service import DEFAULT_CACHE_MAX_BYTES  # noqa: F401


class Session(WorkspaceOps):
    """Workspace-scoped entry point for the declarative v2 API."""

    def __init__(
        self,
        workspace: str,
        block_size: int = blk.DEFAULT_BLOCK_SIZE,
        stats: Optional[IOStats] = None,
        recover: bool = True,
        disk_cache_max_bytes: Optional[int] = None,
    ):
        self.workspace = workspace
        self.block_size = block_size
        # session-scoped accounting by default; GLOBAL_STATS is opt-in
        self.stats = stats if stats is not None else IOStats()
        os.makedirs(workspace, exist_ok=True)
        self.snapshots = SnapshotStore(
            workspace, self.stats, disk_cache_max_bytes=disk_cache_max_bytes
        )
        self.catalog = Catalog(os.path.join(workspace, "catalog.sqlite"), self.stats)
        # referential integrity: deleting a model that snapshots' lineage
        # or a packed layout still references needs an explicit force=True
        self.snapshots.models.add_delete_guard(self.catalog.model_references)
        self.txn = TransactionManager(self.snapshots, self.catalog)
        if recover:
            self.txn.recover()
        self._queue: List[JobHandle] = []
        self._svc: Optional[MergeService] = None
        self._closed = False

    @classmethod
    def _from_parts(
        cls,
        snapshots: SnapshotStore,
        catalog: Catalog,
        txn: TransactionManager,
        block_size: int,
        stats: IOStats,
    ) -> "Session":
        """Internal: wrap an existing substrate (legacy facade delegation)
        without re-opening stores or re-running recovery."""
        sess = cls.__new__(cls)
        sess.workspace = snapshots.workspace
        sess.block_size = block_size
        sess.stats = stats
        sess.snapshots = snapshots
        sess.catalog = catalog
        sess.txn = txn
        sess._queue = []
        sess._svc = None
        sess._closed = False
        return sess

    # ------------------------------------------------------------- service
    def _service(self) -> MergeService:
        """The embedded inline MergeService run_all delegates to: shares
        this session's substrate and stats, runs windows on the calling
        thread (no scheduler thread), and keeps the legacy per-window
        reader lifecycle so I/O accounting is bit-identical."""
        if self._closed:
            raise RuntimeError("Session already closed")
        if self._svc is None:
            self._svc = MergeService._from_parts(
                self.snapshots, self.catalog, self.txn,
                self.block_size, self.stats,
                persistent_cache=False,
            )
        return self._svc

    # ---------------------------------------------------------------- batch
    def submit(
        self, spec: Union[MergeSpec, Dict], sid: Optional[str] = None
    ) -> JobHandle:
        """Queue a merge job (spec object or its dict form) for run_all."""
        if isinstance(spec, dict):
            spec = MergeSpec.from_dict(spec)
        handle = JobHandle(spec, sid=sid)
        self._queue.append(handle)
        return handle

    def run_all(
        self,
        shared_reads: bool = True,
        shared_budget: BudgetLike = None,
        compute: str = "pipelined",
        coalesce: bool = True,
        analyze: bool = True,
        cache_max_bytes: Union[int, None, str] = "auto",
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
        tier_billing: bool = False,
        verify: Any = True,
        execution: str = "local",
        n_workers: Optional[int] = None,
        dist: Any = None,
    ) -> List[MergeResult]:
        """Plan and execute every queued job, sharing expert block reads.

        Compatibility wrapper (see docs/API.md): submits the queued jobs
        to the embedded :class:`~repro.api.service.MergeService` as one
        atomic scheduling window and waits for all of them — the same
        plan-together/share-reads semantics the blocking barrier had,
        now expressed as submit-all/wait-all.

        ``shared_budget`` optionally pools the *union* expert-read bytes
        of each DAG level (see :func:`repro.core.planner.plan_batch`);
        fractions resolve against the naive cost of the level's distinct
        expert set.  ``cache_max_bytes`` bounds the per-level shared-read
        cache (``"auto"`` = 1 GiB, ``None`` = unbounded); blocks beyond
        the cap stream uncached, trading sharing for bounded memory.

        ``compute`` defaults to the overlapped ``"pipelined"`` engine
        (prefetch → windowed vectorized compute → write-behind,
        bit-identical to ``"stream"``; see docs/EXECUTION.md); ``pipeline``
        optionally tunes its window/queue-depth knobs.

        ``prefer_packed=True`` (default) plans and reads each level from
        the most recent **lossless** packed layout covering all of the
        level's experts, when one exists (see docs/STORAGE.md — elision,
        dedup and compression make the same budget buy strictly more
        selected blocks).  Pass a layout id to force a specific layout
        (including lossy ones — an explicit opt-in), or ``False`` to
        always read flat checkpoints.

        ``tier_billing=True`` bills candidate blocks of remote-backed
        experts at their *tier* cost (RAM free, disk cheap, remote full
        price; see docs/STORAGE.md), so a warm cache buys more blocks
        per budget.  Opt-in because the discounted bill changes block
        selection — outputs can differ from an all-local run of the
        same spec (the default keeps selections, and therefore bytes,
        identical to flat local reads).

        ``verify`` controls block verify-on-read against the catalog's
        content hashes (docs/STORAGE.md): ``True`` (default) verifies
        remote/disk-cache and packed reads with read-repair, ``False``
        disables verification, or pass a
        :class:`~repro.store.integrity.VerifyPolicy` to pick tiers
        (e.g. ``VerifyPolicy(flat=True)`` to also check local flat
        reads).

        ``execution="sharded"`` scatters each merge across shard worker
        processes (see docs/DISTRIBUTED.md): the plan's realized read
        set is partitioned on physical bytes, each worker runs the
        pipelined engine over its slice under a per-shard budget, and
        the coordinator splices the staged regions into one atomic
        commit — bit-identical to local execution.  ``n_workers`` is a
        convenience for the common case; pass a
        :class:`repro.dist.DistOptions` as ``dist`` for full control
        (transport, worker kernel, lease re-issue limits).
        Returns results in submission order; handles cancelled while
        still queued are dropped from the batch (and from the results).
        """
        queued = list(self._queue)
        # a handle cancelled while still session-queued must never
        # execute: it is dropped from the batch (and from the results)
        jobs = [h for h in queued if h.status not in JobState.TERMINAL]
        if not jobs:
            self._queue = self._queue[len(queued):]
            return []
        svc = self._service()
        if n_workers is not None and dist is None:
            from repro.dist.lease import DistOptions

            dist = DistOptions(n_workers=n_workers)
        if dist is not None:
            execution = "sharded"
        opts = WindowOptions(
            shared_reads=shared_reads,
            shared_budget=shared_budget,
            compute=compute,
            coalesce=coalesce,
            analyze=analyze,
            cache_max_bytes=cache_max_bytes,
            pipeline=pipeline,
            prefer_packed=prefer_packed,
            tier_billing=tier_billing,
            verify=verify,
            execution=execution,
            dist=dist,
        )
        # one atomic group: the whole batch is a single scheduling window
        # (plan-together semantics, batch-wide sid validation)
        token = "batch-" + uuid.uuid4().hex[:8]
        shandles = [
            svc.submit(h.spec, sid=h.requested_sid, _opts=opts, _group=token)
            for h in jobs
        ]
        svc.drain()

        # a failed/never-run job leaves the session queue intact so the
        # batch can be fixed and rerun (completed named nodes are adopted,
        # not re-executed, on the retry)
        for sh in shandles:
            if sh.status != JobState.DONE:
                sh.wait(0)  # re-raises the recorded error
        results: List[MergeResult] = []
        for handle, sh in zip(jobs, shandles):
            handle._finish(sh.result)
            results.append(sh.result)
        self._queue = self._queue[len(queued):]
        return results

    # ---------------------------------------------------------------- packed
    def repack(
        self,
        model_ids: Sequence[str],
        base_id: str,
        layout_id: Optional[str] = None,
        options: Optional["Any"] = None,
    ) -> Dict:
        """Rewrite checkpoints into a content-addressed packed layout
        (store/packed): cross-model dedup, zero-delta elision, optional
        downcast/compression.  Returns the repack report; subsequent
        ``run``/``run_all`` calls auto-prefer the layout when lossless.
        """
        return self.snapshots.packed.repack(
            base_id,
            list(model_ids),
            self.block_size,
            layout_id=layout_id,
            options=options,
            catalog=self.catalog,
        )

    def list_layouts(self) -> List[str]:
        return self.catalog.list_packed_layouts()

    def _select_layout(
        self,
        prefer_packed: Union[bool, str],
        expert_ids: List[str],
        base_ids: List[str],
    ) -> Optional[str]:
        """Compatibility delegate — layout selection lives on the
        embedded MergeService now."""
        return self._service()._select_layout(
            prefer_packed, expert_ids, base_ids
        )

    # ------------------------------------------------------------- one-shot
    def run(
        self,
        spec: Union[MergeSpec, Dict],
        sid: Optional[str] = None,
        compute: str = "pipelined",
        coalesce: bool = True,
        analyze: bool = True,
        pipeline: Optional[PipelineConfig] = None,
        prefer_packed: Union[bool, str] = True,
    ) -> MergeResult:
        """Submit one spec (possibly a whole merge graph) and execute it."""
        handle = self.submit(spec, sid=sid)
        self.run_all(
            shared_reads=True, compute=compute, coalesce=coalesce,
            analyze=analyze, pipeline=pipeline, prefer_packed=prefer_packed,
        )
        assert handle.result is not None
        return handle.result

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Release the session (idempotent)."""
        if self._closed:
            return
        self._closed = True
        if self._svc is not None:
            self._svc.close()
            self._svc = None
        self.catalog.close()
