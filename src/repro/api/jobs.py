"""Job model for the asynchronous :class:`~repro.api.service.MergeService`.

A merge job moves through a small state machine::

    pending ──submit──> queued ──admission──> admitted ──window──> running
                          │                      │                    │
                          │ reject               │ cancel             │ cancel
                          v                      v                    v
                       rejected              cancelled            cancelled
                                                                      │ error
                                                          done <──────┴──> failed

``pending`` is the pre-service state used by :meth:`Session.submit`
(jobs queued locally until ``run_all`` hands them to the service).
A job whose execution dies on a *transient* fault (simulated or real
worker death, I/O timeouts) is requeued with jittered backoff and — when
a progress journal survives — resumed at its block-level high-water
mark; after ``max_job_attempts`` such deaths it lands in the terminal
``quarantined`` state (poison work that keeps killing workers must not
be retried forever).  See docs/RECOVERY.md.
Admission control happens *before* any parameter I/O: a job whose hard
byte demand cannot fit the budget pool is rejected (or held queued,
depending on the service's admission policy) — never aborted
mid-execution for budget reasons.

:class:`JobHandle` is the future-style handle returned by
``MergeService.submit()``: ``wait()`` blocks for (and returns) the
committed :class:`~repro.core.executor.MergeResult`, ``status`` /
``progress()`` observe execution from any thread, and ``cancel()``
requests cooperative cancellation — a running job aborts crash-safely
through the transaction manager (no partial snapshot ever becomes
visible).
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Any, Dict, Optional


class JobState:
    """String constants for the job state machine (JSON/catalog friendly)."""

    PENDING = "pending"      # created, not yet handed to a service
    QUEUED = "queued"        # submitted, awaiting admission
    ADMITTED = "admitted"    # past admission control, awaiting a window
    RUNNING = "running"      # executing inside a scheduling window
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"
    REJECTED = "rejected"    # refused at admission (budget pool)
    QUARANTINED = "quarantined"  # poison: crashed/retried past the limit

    TERMINAL = frozenset({DONE, FAILED, CANCELLED, REJECTED, QUARANTINED})


class JobCancelled(RuntimeError):
    """Raised by :meth:`JobHandle.wait` when the job was cancelled."""


class AdmissionRejected(RuntimeError):
    """Raised by :meth:`JobHandle.wait` when admission control refused
    the job (its hard byte demand exceeds the remaining budget pool)."""


class DeadlineExceeded(RuntimeError):
    """Raised by :meth:`JobHandle.wait` when the job's deadline passed
    before a scheduling window could run it."""


class JobHandle:
    """Future-style handle for one submitted merge job.

    Thread-safe: the service mutates it from the scheduler thread while
    any number of caller threads ``wait()`` / ``cancel()`` / observe.
    """

    def __init__(
        self,
        spec,
        sid: Optional[str] = None,
        tenant: str = "default",
        priority: int = 0,
        deadline: Optional[float] = None,
        job_id: Optional[str] = None,
    ):
        self.spec = spec
        self.requested_sid = sid
        self.tenant = tenant
        self.priority = int(priority)
        #: relative seconds from submission; bound to an absolute wall
        #: clock instant by the service at submit()
        self.deadline = deadline
        self.job_id = job_id or "job-" + uuid.uuid4().hex[:12]
        self.sid: Optional[str] = None
        self.window_id: Optional[str] = None
        #: admission record: {"decision", "kind", "demand_b", ...}
        self.admission: Optional[Dict[str, Any]] = None
        self.submitted_at: Optional[float] = None
        self.finished_at: Optional[float] = None

        self._lock = threading.Lock()
        self._terminal = threading.Event()
        self._cancel_event = threading.Event()
        self._state = JobState.PENDING
        self._result = None
        self._error: Optional[BaseException] = None
        self._progress: Dict[str, Any] = {"blocks_done": 0, "blocks_total": None}
        self._service = None  # set by MergeService.submit

    # ------------------------------------------------------------- queries
    @property
    def status(self) -> str:
        with self._lock:
            return self._state

    @property
    def result(self):
        """The committed MergeResult, or None while not done."""
        return self._result

    @result.setter
    def result(self, value) -> None:  # legacy Session handles assign this
        self._result = value

    @property
    def done(self) -> bool:
        return self._result is not None

    def progress(self) -> Dict[str, Any]:
        """Point-in-time view: state, sid (once known), blocks done/total."""
        with self._lock:
            out = dict(self._progress)
            out["state"] = self._state
            out["sid"] = self.sid
            total = out.get("blocks_total")
            done = out.get("blocks_done") or 0
            out["fraction"] = (done / total) if total else None
            return out

    # --------------------------------------------------------------- wait
    def wait(self, timeout: Optional[float] = None):
        """Block until the job reaches a terminal state; return the
        MergeResult on success, raise on failure / cancel / rejection."""
        if self._service is None and self.status == JobState.PENDING:
            raise RuntimeError(
                f"job {self.job_id} was queued on a Session but never "
                f"submitted to a MergeService — call Session.run_all()"
            )
        if not self._terminal.wait(timeout):
            raise TimeoutError(
                f"job {self.job_id} not finished after {timeout}s "
                f"(state={self.status})"
            )
        with self._lock:
            if self._state == JobState.DONE:
                return self._result
            err = self._error
            if err is None:
                if self._state == JobState.CANCELLED:
                    err = JobCancelled(f"job {self.job_id} was cancelled")
                elif self._state == JobState.REJECTED:
                    err = AdmissionRejected(f"job {self.job_id} was rejected")
                else:
                    err = RuntimeError(f"job {self.job_id} failed")
        raise err

    # ------------------------------------------------------------- cancel
    def cancel(self) -> bool:
        """Request cancellation.  Returns True if this job is abandoned:
        queued jobs cancel immediately, running jobs abort at the next
        executor checkpoint (crash-safe — staged output is discarded,
        nothing is published) and ``wait()`` raises :class:`JobCancelled`.
        When the job's work is deduped with another live job's, that
        other job may still commit the shared snapshot — this handle
        still resolves cancelled.  Returns False when the job already
        reached a terminal state."""
        svc = self._service
        if svc is not None:
            return svc._cancel_job(self)
        with self._lock:
            if self._state == JobState.PENDING:
                self._state = JobState.CANCELLED
                self._error = JobCancelled(f"job {self.job_id} was cancelled")
                self.finished_at = time.time()
                self._terminal.set()
                return True
        return False

    @property
    def cancel_requested(self) -> bool:
        return self._cancel_event.is_set()

    # ----------------------------------------- service-side transitions
    def _set_state(self, state: str) -> None:
        with self._lock:
            if self._state not in JobState.TERMINAL:
                self._state = state

    def _update_progress(self, blocks_done: int, blocks_total: int) -> None:
        with self._lock:
            self._progress["blocks_done"] = blocks_done
            self._progress["blocks_total"] = blocks_total

    def _finish(self, result, finished_at: Optional[float] = None) -> None:
        with self._lock:
            if self._state in JobState.TERMINAL:
                return
            self._state = JobState.DONE
            self._result = result
            self.sid = result.sid
            self.finished_at = (
                finished_at if finished_at is not None else time.time()
            )
            self._terminal.set()

    def _fail(
        self,
        error: BaseException,
        state: str = JobState.FAILED,
        finished_at: Optional[float] = None,
    ) -> None:
        with self._lock:
            if self._state in JobState.TERMINAL:
                return
            self._state = state
            self._error = error
            self.finished_at = (
                finished_at if finished_at is not None else time.time()
            )
            self._terminal.set()

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"JobHandle({self.job_id}, spec={self.spec.spec_id}, "
            f"tenant={self.tenant!r}, state={self.status}, "
            f"sid={self.sid or self.requested_sid})"
        )
