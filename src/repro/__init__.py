"""repro — MergePipe (budget-aware LLM merging) on a multi-pod JAX stack."""
__version__ = "1.0.0"
